//! Parameter-server substrate — the *centralized* baselines.
//!
//! The paper's §II-A baselines, built so the decentralized claim can be
//! tested rather than assumed:
//!
//! * **ASGD** — workers push raw gradients; the PS applies
//!   `w ← w − η·U(g)` and returns the fresh weights.
//! * **DC-ASGD** (Zheng et al.) — the PS additionally keeps a backup
//!   `w_bak(i)` of the weights it last sent to worker `i` and corrects
//!   each incoming gradient with
//!   `g̃ = g + λ g ⊙ g ⊙ (w_ps − w_bak(i))` before applying it.
//!
//! The PS is an actor on its own thread; workers talk to it over
//! channels. Timing follows Eq. 15: each request costs the worker
//! `t_W2PS = 2·ptp(n)` of network time plus queueing at the server
//! (service time `serve_s` per request, requests serialized) — the
//! many-to-few bottleneck the paper attributes to centralized schemes.
//!
//! Under a hierarchical (dragonfly) fabric the crossings **contend**:
//! every worker outside the PS's group funnels through that group's
//! tapered global links, so each remote transfer is priced at the
//! concurrent-crossing count through
//! [`NetModel::ptp_time_between_flows`] (the same
//! [`crate::comm::GlobalContention`] model the collective schedules
//! use) — the many-to-few bottleneck now includes the fabric's share
//! of it, not just the server's.

pub mod sharded;
pub use sharded::ShardedPs;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::comm::{AllReduceAlgo, NetModel};
use crate::dc;
use crate::exec::Gate;
use crate::optim::Optimizer;

/// Mode of the server's update rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PsMode {
    /// Plain asynchronous SGD (stale, uncompensated).
    Asgd,
    /// Delay-compensated ASGD with dynamic λ (Eq. 17 applied to
    /// `D = w_ps − w_bak(i)`).
    DcAsgd { lam0: f32 },
}

/// A gradient push from a worker.
struct PushMsg {
    worker: usize,
    grad: Vec<f32>,
    /// Worker's virtual send time.
    sent_at: f64,
    /// LR for this update (schedule-resolved by the worker).
    eta: f32,
    wd: f32,
    reply: Sender<PullReply>,
}

/// The server's reply: fresh weights + the virtual time the exchange
/// completed from the worker's perspective.
pub struct PullReply {
    pub weights: Vec<f32>,
    pub done_at: f64,
    /// ‖w_ps − w_bak(worker)‖ *before* this update was applied — the
    /// distance series of experiment E4 (DESIGN.md §5).
    pub staleness_dist: f64,
}

enum Msg {
    Push(PushMsg),
    Stop,
}

/// Handle each worker uses to talk to the PS.
#[derive(Clone)]
pub struct PsClient {
    tx: Sender<Msg>,
    net: NetModel,
    n_params: usize,
    /// Concurrent cross-group crossings each remote transfer shares the
    /// PS group's tapered global links with (1 on flat fabrics).
    flows: usize,
    /// Engine-pool execution gate (see [`crate::exec`]): the blocking
    /// reply wait releases its runnable permit so a worker parked on
    /// the PS never occupies a `--threads` slot. Unlimited by default.
    gate: Arc<Gate>,
}

impl PsClient {
    /// Plug the engine pool's execution [`Gate`] into this client's
    /// blocking reply waits. The PS actor itself is service
    /// infrastructure and stays ungated.
    pub fn set_gate(&mut self, gate: Arc<Gate>) {
        self.gate = gate;
    }
    /// Push a gradient and (blocking) pull fresh weights — the ASGD
    /// round-trip. `now` is the worker's virtual time.
    ///
    /// Transfer time is topology-aware: the PS is hosted next to rank 0
    /// (same dragonfly group), so under a hierarchical schedule a
    /// worker in group 0 pays local-link latency while everyone else
    /// crosses the optics — **contended** by every other remote
    /// worker's crossings into the PS group — the placement asymmetry
    /// (and oversubscription) the flat model couldn't express.
    pub fn push_pull(&self, worker: usize, grad: Vec<f32>, now: f64, eta: f32, wd: f32) -> PullReply {
        assert_eq!(grad.len(), self.n_params);
        let (reply_tx, reply_rx) = channel();
        let ptp = self.net.ptp_time_between_flows(worker, 0, self.n_params, self.flows);
        // Worker→PS transfer time happens before the server sees it.
        let arrive = now + ptp;
        self.tx
            .send(Msg::Push(PushMsg { worker, grad, sent_at: arrive, eta, wd, reply: reply_tx }))
            .expect("ps alive");
        // Hand the runnable permit back while blocked on the server.
        self.gate.release();
        let recv = reply_rx.recv();
        self.gate.acquire();
        let mut reply = recv.expect("ps alive");
        // PS→worker transfer for the fresh weights.
        reply.done_at += ptp;
        reply
    }
}

/// The running server; join to collect final weights.
pub struct ParameterServer {
    tx: Sender<Msg>,
    handle: JoinHandle<(Vec<f32>, u64)>,
    net: NetModel,
    n_params: usize,
    /// Worst-case concurrent crossings into the PS group (the workers
    /// outside it); prices every remote transfer's contention.
    flows: usize,
}

impl ParameterServer {
    /// Spawn the PS actor with initial weights, an optimizer for the
    /// update rule `U`, the number of workers, and a per-request service
    /// time (models the PS's CPU/NIC; Eq. 15's "time spent ... waiting
    /// for the PS").
    pub fn spawn(
        init_w: Vec<f32>,
        mut opt: Box<dyn Optimizer>,
        n_workers: usize,
        mode: PsMode,
        net: NetModel,
        serve_s: f64,
    ) -> Self {
        let n_params = init_w.len();
        assert_eq!(opt.n_params(), n_params);
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let handle = std::thread::spawn(move || {
            let mut w = init_w;
            // w_bak(i): weights last sent to worker i (DC-ASGD state).
            let mut bak: Vec<Vec<f32>> = (0..n_workers).map(|_| w.clone()).collect();
            let mut delta = vec![0.0f32; n_params];
            let mut gtilde = vec![0.0f32; n_params];
            // Server busy-until time (requests serialized — the
            // many-to-few bottleneck).
            let mut busy_until = 0.0f64;
            let mut updates = 0u64;
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Stop => break,
                    Msg::Push(p) => {
                        let start = busy_until.max(p.sent_at);
                        let done = start + serve_s;
                        busy_until = done;
                        let staleness_dist = crate::tensor::dist2(&w, &bak[p.worker]);
                        let g = match mode {
                            PsMode::Asgd => &p.grad,
                            PsMode::DcAsgd { lam0 } => {
                                // D = w_ps − w_bak(i)  (Eq. 5/6 with the
                                // PS's and worker's weight copies)
                                let d: Vec<f32> = w
                                    .iter()
                                    .zip(&bak[p.worker])
                                    .map(|(a, b)| a - b)
                                    .collect();
                                let lam = dc::dynamic_lambda(&p.grad, &d, lam0);
                                dc::dc_correct(&p.grad, &d, lam, &mut gtilde);
                                &gtilde
                            }
                        };
                        opt.step(g, &w, p.eta, p.wd, &mut delta);
                        crate::tensor::add_assign(&mut w, &delta);
                        updates += 1;
                        bak[p.worker].copy_from_slice(&w);
                        let _ = p.reply.send(PullReply {
                            weights: w.clone(),
                            done_at: done,
                            staleness_dist,
                        });
                    }
                }
            }
            (w, updates)
        });
        // Contention: every worker outside the PS's dragonfly group
        // funnels through that group's tapered global links; price each
        // remote transfer at the worst-case concurrent crossing count.
        let flows = match net.algo {
            AllReduceAlgo::Hierarchical(d) => {
                let ps_group = d.group_of(0);
                (0..n_workers).filter(|&r| d.group_of(r) != ps_group).count().max(1)
            }
            _ => 1,
        };
        ParameterServer { tx, handle, net, n_params, flows }
    }

    pub fn client(&self) -> PsClient {
        PsClient {
            tx: self.tx.clone(),
            net: self.net,
            n_params: self.n_params,
            flows: self.flows,
            gate: Gate::unlimited(),
        }
    }

    /// Stop the server and return (final weights, update count).
    pub fn shutdown(self) -> (Vec<f32>, u64) {
        let _ = self.tx.send(Msg::Stop);
        self.handle.join().expect("ps thread")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::optim::MomentumSgd;

    fn plain_sgd(n: usize) -> Box<dyn Optimizer> {
        Box::new(MomentumSgd::new(n, 0.0))
    }

    #[test]
    fn asgd_applies_updates_in_arrival_order() {
        let ps = ParameterServer::spawn(
            vec![0.0; 2],
            plain_sgd(2),
            2,
            PsMode::Asgd,
            NetModel::instant(),
            0.0,
        );
        let c = ps.client();
        let r1 = c.push_pull(0, vec![1.0, 0.0], 0.0, 1.0, 0.0);
        assert_eq!(r1.weights, vec![-1.0, 0.0]);
        let r2 = c.push_pull(1, vec![0.0, 2.0], 0.0, 1.0, 0.0);
        assert_eq!(r2.weights, vec![-1.0, -2.0]);
        let (w, n) = ps.shutdown();
        assert_eq!(w, vec![-1.0, -2.0]);
        assert_eq!(n, 2);
    }

    #[test]
    fn service_time_serializes_requests() {
        // Two pushes at t=0 with serve_s = 1: the second completes at 2.
        let ps = ParameterServer::spawn(
            vec![0.0; 1],
            plain_sgd(1),
            2,
            PsMode::Asgd,
            NetModel::instant(),
            1.0,
        );
        let c = ps.client();
        let r1 = c.push_pull(0, vec![0.1], 0.0, 1.0, 0.0);
        let r2 = c.push_pull(1, vec![0.1], 0.0, 1.0, 0.0);
        assert!((r1.done_at - 1.0).abs() < 1e-12);
        assert!((r2.done_at - 2.0).abs() < 1e-12);
        ps.shutdown();
    }

    #[test]
    fn network_time_added_both_ways() {
        let net = NetModel { alpha_s: 0.5, beta_bytes_per_s: f64::INFINITY, algo: crate::comm::AllReduceAlgo::Ring };
        let ps = ParameterServer::spawn(
            vec![0.0; 1],
            plain_sgd(1),
            1,
            PsMode::Asgd,
            net,
            0.0,
        );
        let c = ps.client();
        let r = c.push_pull(0, vec![0.1], 10.0, 1.0, 0.0);
        // 10 + α (push) + 0 (serve) + α (pull) = 11
        assert!((r.done_at - 11.0).abs() < 1e-12, "{}", r.done_at);
        ps.shutdown();
    }

    #[test]
    fn hierarchical_net_penalizes_cross_group_workers() {
        // PS sits with rank 0: a worker in another dragonfly group pays
        // the global link both ways.
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        let net = NetModel {
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
            ..NetModel::default()
        };
        let ps = ParameterServer::spawn(
            vec![0.0; 1],
            plain_sgd(1),
            4,
            PsMode::Asgd,
            net,
            0.0,
        );
        let c = ps.client();
        let local = c.push_pull(1, vec![0.1], 0.0, 1.0, 0.0).done_at;
        let remote = c.push_pull(2, vec![0.1], 0.0, 1.0, 0.0).done_at;
        assert!(remote > local, "cross-group round-trip {remote} not slower than {local}");
        ps.shutdown();
    }

    #[test]
    fn contended_optics_slow_remote_workers_only() {
        // 2 groups of 2, taper 1: the two remote workers' crossings
        // share one optic (slowdown 2). Same config at taper 2 rides
        // dedicated links — remote round-trips must be strictly slower
        // under contention, local ones identical.
        let run = |taper: usize| {
            let d = crate::comm::Dragonfly {
                groups: 2,
                nodes_per_group: 2,
                global_taper: taper,
                ..Default::default()
            };
            let net = NetModel {
                algo: crate::comm::AllReduceAlgo::Hierarchical(d),
                ..NetModel::default()
            };
            let ps = ParameterServer::spawn(
                vec![0.0; 1000],
                plain_sgd(1000),
                4,
                PsMode::Asgd,
                net,
                0.0,
            );
            let c = ps.client();
            let local = c.push_pull(1, vec![0.1; 1000], 0.0, 1.0, 0.0).done_at;
            let remote = c.push_pull(2, vec![0.1; 1000], 0.0, 1.0, 0.0).done_at;
            ps.shutdown();
            (local, remote)
        };
        let (local_ded, remote_ded) = run(2);
        let (local_con, remote_con) = run(1);
        assert_eq!(local_con, local_ded, "same-group transfers must not contend");
        assert!(
            remote_con > remote_ded,
            "contended crossing {remote_con} not slower than dedicated {remote_ded}"
        );
    }

    #[test]
    fn dcasgd_tracks_backup_distance() {
        let ps = ParameterServer::spawn(
            vec![0.0; 2],
            plain_sgd(2),
            2,
            PsMode::DcAsgd { lam0: 0.2 },
            NetModel::instant(),
            0.0,
        );
        let c = ps.client();
        // worker 0 updates once: its backup is now fresh.
        let r0 = c.push_pull(0, vec![1.0, 1.0], 0.0, 0.5, 0.0);
        assert_eq!(r0.staleness_dist, 0.0); // first push: bak == w
        // worker 1 still has the t=0 backup: distance > 0.
        let r1 = c.push_pull(1, vec![1.0, 1.0], 0.0, 0.5, 0.0);
        assert!(r1.staleness_dist > 0.0);
        // worker 0 pushes again immediately: bak is current ⇒ dist 0 ...
        // but worker 1's update happened in between, so dist > 0 again.
        let r0b = c.push_pull(0, vec![1.0, 1.0], 0.0, 0.5, 0.0);
        assert!(r0b.staleness_dist > 0.0);
        ps.shutdown();
    }

    #[test]
    fn dcasgd_correction_changes_update() {
        // Same gradient stream, with and without compensation, must give
        // different weights once staleness exists.
        let run = |mode| {
            let ps = ParameterServer::spawn(
                vec![0.5; 4],
                plain_sgd(4),
                2,
                mode,
                NetModel::instant(),
                0.0,
            );
            let c = ps.client();
            for it in 0..5 {
                let g = vec![0.1 * (it + 1) as f32; 4];
                c.push_pull(0, g.clone(), it as f64, 0.3, 0.0);
                c.push_pull(1, g, it as f64, 0.3, 0.0);
            }
            ps.shutdown().0
        };
        let plain = run(PsMode::Asgd);
        let comp = run(PsMode::DcAsgd { lam0: 0.2 });
        assert_ne!(plain, comp);
    }
}
