//! Hand-rolled CLI argument parsing (offline build: no `clap`).
//!
//! Grammar: `dcs3gd <subcommand> [--key value | --flag] ...`.
//! Subcommands and their options are declared by the binary; this module
//! provides the splitting, typed lookup and usage errors.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: subcommand + `--key value` options + bare flags.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut out = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_opts_flags() {
        // note: a bare word after `--verbose` would be consumed as its
        // value (the usual greedy convention); flags go last or use `=`.
        let a = parse("train --config cfg.toml --nodes 8 pos1 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("config"), Some("cfg.toml"));
        assert_eq!(a.get_usize("nodes", 1).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --algo=ring --n=4");
        assert_eq!(a.get("algo"), Some("ring"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 4);
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
        assert!(a.require("missing").is_err());
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
