//! Minimal TOML-subset parser (offline build: no `toml` crate).
//!
//! Supported: `[section]` headers (arbitrarily dotted), `[[section]]`
//! table-array headers (each occurrence appends one table; keys below
//! it fill that table), `key = value` with strings, integers, floats,
//! booleans and flat arrays, `#` comments, blank lines. Section keys
//! are exposed flattened as `"section.key"`; a table array is exposed
//! as `"section"` → [`TomlValue::Array`] of [`TomlValue::Table`]s. That
//! covers every config file this project ships; anything fancier is a
//! parse error, not a silent misread.

use std::collections::BTreeMap;

use thiserror::Error;

/// A TOML scalar, flat array, or table (the element of a `[[...]]`
/// table array).
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, TomlValue>> {
        match self {
            TomlValue::Table(t) => Some(t),
            _ => None,
        }
    }
}

#[derive(Debug, Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Where the current `key = value` lines land.
enum Target {
    /// Flattened `section.key` (empty section = document root).
    Section(String),
    /// The newest table of the `[[name]]` array at `out[name]`.
    ArrayTable(String),
}

/// Parse a TOML-subset document into flattened `section.key → value`
/// (plus `name → Array(Table, ...)` for table arrays).
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out: BTreeMap<String, TomlValue> = BTreeMap::new();
    let mut target = Target::Section(String::new());
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let err = |msg: String| TomlError { line: line_no, msg };
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest
                .strip_suffix("]]")
                .ok_or_else(|| err("unterminated [[section]]".into()))?
                .trim();
            if name.is_empty() {
                return Err(err("empty table-array name".into()));
            }
            let entry =
                out.entry(name.to_string()).or_insert_with(|| TomlValue::Array(Vec::new()));
            match entry {
                TomlValue::Array(tables)
                    if tables.iter().all(|t| matches!(t, TomlValue::Table(_))) =>
                {
                    tables.push(TomlValue::Table(BTreeMap::new()));
                }
                _ => return Err(err(format!("{name:?} is already a non-table-array value"))),
            }
            target = Target::ArrayTable(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated [section]".into()))?
                .trim();
            if name.is_empty() {
                return Err(err("empty section name".into()));
            }
            target = Target::Section(name.to_string());
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected key = value".into()))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key".into()));
        }
        let val = parse_value(line[eq + 1..].trim()).map_err(&err)?;
        match &target {
            Target::Section(section) => {
                let full = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                out.insert(full, val);
            }
            Target::ArrayTable(name) => {
                let Some(TomlValue::Array(tables)) = out.get_mut(name) else {
                    return Err(err(format!("internal: lost table array {name:?}")));
                };
                let Some(TomlValue::Table(table)) = tables.last_mut() else {
                    return Err(err(format!("internal: empty table array {name:?}")));
                };
                table.insert(key.to_string(), val);
            }
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a # inside a quoted string must survive
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape {other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        return inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(TomlValue::Array);
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let doc = r#"
            # a config
            name = "run1"       # trailing comment
            steps = 1_000
            lr = 0.1
            debug = false

            [net]
            alpha = 1.5e-6
            algo = "ring"
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["name"], TomlValue::Str("run1".into()));
        assert_eq!(m["steps"], TomlValue::Int(1000));
        assert_eq!(m["lr"], TomlValue::Float(0.1));
        assert_eq!(m["debug"], TomlValue::Bool(false));
        assert_eq!(m["net.alpha"].as_f64(), Some(1.5e-6));
        assert_eq!(m["net.algo"].as_str(), Some("ring"));
    }

    #[test]
    fn arrays() {
        let m = parse("xs = [1, 2, 3]\nys = [0.5, \"a\"]").unwrap();
        assert_eq!(
            m["xs"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
    }

    #[test]
    fn table_arrays() {
        let doc = r#"
            [control]
            policy = "fixed"

            [[control.fault]]
            rank = 0
            kind = "kill"
            at_s = 1.0

            [[control.fault]]
            rank = 2
            kind = "slow"
            at_s = 0.5
            factor = 3.0

            [eval]
            every = 10
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["control.policy"].as_str(), Some("fixed"));
        assert_eq!(m["eval.every"].as_i64(), Some(10));
        let faults = m["control.fault"].as_array().unwrap();
        assert_eq!(faults.len(), 2);
        let f0 = faults[0].as_table().unwrap();
        assert_eq!(f0["rank"].as_i64(), Some(0));
        assert_eq!(f0["kind"].as_str(), Some("kill"));
        let f1 = faults[1].as_table().unwrap();
        assert_eq!(f1["factor"].as_f64(), Some(3.0));
    }

    #[test]
    fn table_array_conflicts_rejected() {
        // a scalar key cannot become a table array
        assert!(parse("x = 1\n[[x]]\ny = 2").is_err());
        assert!(parse("[[broken\nx = 1").is_err());
        assert!(parse("[[]]").is_err());
    }

    #[test]
    fn string_with_hash_and_escape() {
        let m = parse(r#"s = "a#b\n" "#).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b\n"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, 2").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let m = parse("a = -3\nb = -0.5\nc = 1e-6").unwrap();
        assert_eq!(m["a"].as_i64(), Some(-3));
        assert_eq!(m["b"].as_f64(), Some(-0.5));
        assert_eq!(m["c"].as_f64(), Some(1e-6));
    }
}
