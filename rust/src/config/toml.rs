//! Minimal TOML-subset parser (offline build: no `toml` crate).
//!
//! Supported: `[section]` headers (arbitrarily dotted), `key = value`
//! with strings, integers, floats, booleans and flat arrays, `#`
//! comments, blank lines. Keys are exposed flattened as
//! `"section.key"`. That covers every config file this project ships;
//! anything fancier is a parse error, not a silent misread.

use std::collections::BTreeMap;

use thiserror::Error;

/// A TOML scalar or flat array.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Parse a TOML-subset document into flattened `section.key → value`.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| TomlError { line: line_no, msg: "unterminated [section]".into() })?
                .trim();
            if name.is_empty() {
                return Err(TomlError { line: line_no, msg: "empty section name".into() });
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| TomlError { line: line_no, msg: "expected key = value".into() })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(TomlError { line: line_no, msg: "empty key".into() });
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|msg| TomlError { line: line_no, msg })?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full, val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a # inside a quoted string must survive
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape {other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        return inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(TomlValue::Array);
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let doc = r#"
            # a config
            name = "run1"       # trailing comment
            steps = 1_000
            lr = 0.1
            debug = false

            [net]
            alpha = 1.5e-6
            algo = "ring"
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["name"], TomlValue::Str("run1".into()));
        assert_eq!(m["steps"], TomlValue::Int(1000));
        assert_eq!(m["lr"], TomlValue::Float(0.1));
        assert_eq!(m["debug"], TomlValue::Bool(false));
        assert_eq!(m["net.alpha"].as_f64(), Some(1.5e-6));
        assert_eq!(m["net.algo"].as_str(), Some("ring"));
    }

    #[test]
    fn arrays() {
        let m = parse("xs = [1, 2, 3]\nys = [0.5, \"a\"]").unwrap();
        assert_eq!(
            m["xs"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
    }

    #[test]
    fn string_with_hash_and_escape() {
        let m = parse(r#"s = "a#b\n" "#).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b\n"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, 2").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let m = parse("a = -3\nb = -0.5\nc = 1e-6").unwrap();
        assert_eq!(m["a"].as_i64(), Some(-3));
        assert_eq!(m["b"].as_f64(), Some(-0.5));
        assert_eq!(m["c"].as_f64(), Some(1e-6));
    }
}
