//! Experiment configuration: typed config with defaults matching the
//! paper's §IV-A hyper-parameters, a builder for programmatic use, and
//! TOML loading for the CLI.

mod toml;

pub use toml::{parse as parse_toml, TomlError, TomlValue};

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::algo::Algo;
use crate::comm::{AllReduceAlgo, Dragonfly, NetModel, SimBackend};
use crate::compress::{CompressConfig, CompressorKind};
use crate::control::{
    ControlConfig, ControlPolicy, FaultEvent, FaultKind, FaultPlan, JoinEvent, ProbeMode,
};
use crate::exec::PerfConfig;
use crate::hetero::{HeteroConfig, HeteroProfile};
use crate::simtime::ComputeModel;

/// Full description of one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Run name (used for output files).
    pub name: String,
    /// Backend: an artifact variant directory name (e.g.
    /// `"tiny_cnn_b32"`) or `"linear"` for the pure-rust reference model.
    pub variant: String,
    /// Where artifact variants live.
    pub artifacts_root: PathBuf,
    pub algo: Algo,
    /// Number of workers N.
    pub nodes: usize,
    /// Per-worker mini-batch |B|/N.
    pub local_batch: usize,
    /// Per-worker training iterations.
    pub steps: u64,
    pub seed: u64,

    // --- optimizer & schedules (paper §IV-A defaults) ---
    /// `"momentum"`, `"lars"` or `"adam"`.
    pub optimizer: String,
    /// Momentum μ.
    pub momentum: f32,
    /// Single-node reference LR η_sn (0.1 for ResNet@256, 0.02 for VGG).
    pub eta_single: f32,
    /// Reference batch for the Eq. 16 linear-scaling rule.
    pub base_batch: usize,
    /// Planned warmup length as a fraction of total iterations (paper:
    /// one half).
    pub warmup_frac: f32,
    /// Where warmup actually stops (plateau), as a fraction of total
    /// iterations (paper: 15/90 ≈ 0.17 of the run for ≤64k batches).
    pub warmup_stop_frac: f32,
    /// Base weight decay (paper: 1e-4).
    pub weight_decay: f32,
    /// The paper's constant k multiplying weight decay to compensate the
    /// scheduled decay (k = 2.3).
    pub wd_k: f32,
    /// Variance-control base λ0 (Eq. 17; paper: 0.2). 0 disables the
    /// compensation (the S3GD ablation).
    pub lam0: f32,
    /// Maximum staleness (paper trains with 1; §V proposes more).
    pub staleness: usize,

    // --- data ---
    pub n_train: usize,
    pub n_val: usize,
    pub data_noise: f32,

    // --- simulation models ---
    pub net: NetModel,
    /// Dragonfly topology for the hierarchical collective schedule —
    /// the `[comm]` table. Used directly when `net.algo` is
    /// `Hierarchical`, and as the candidate topology the
    /// `schedule_coupled` control policy prices against the flat
    /// fabric (see [`ExperimentConfig::topology`]).
    pub dragonfly: Dragonfly,
    pub compute: ComputeModel,
    /// If true, drive worker virtual time from measured PJRT wall time
    /// instead of `compute` (used by e2e runs on the real backend).
    pub time_from_wall: bool,

    // --- control plane ---
    /// Elastic control plane: staleness policy, fault schedule, recovery
    /// (the `[control]` TOML table; see [`crate::control`]).
    pub control: ControlConfig,

    // --- gradient compression ---
    /// Error-feedback gradient compression (the `[compress]` TOML
    /// table; see [`crate::compress`]). Default: off.
    pub compress: CompressConfig,

    // --- heterogeneity ---
    /// Heterogeneous-fabric subsystem (the `[hetero]` TOML table; see
    /// [`crate::hetero`]): compute tiers, link asymmetry, spot
    /// revocations, diurnal load. Default: off.
    pub hetero: HeteroConfig,

    // --- engine core ---
    /// Simulator execution knobs (the `[perf]` TOML table; see
    /// [`crate::exec`]): worker-pool thread budget and kernel chunk
    /// width. Wall-clock only — results are bit-identical for every
    /// setting.
    pub perf: PerfConfig,

    /// Simulator backend selection (the `[sim]` TOML table): `dense`
    /// materializes every rank (bit-exact reference, threads-parallel),
    /// `folded` resolves rounds from contributor-count deltas so only
    /// posting ranks are stored. Both produce bit-identical results;
    /// the knob is excluded from run JSON for that reason.
    pub sim: SimConfig,

    /// Trace/observability knobs (the `[trace]` TOML table; see
    /// [`crate::obs`] and `docs/observability.md`): journal ring
    /// capacity and the optional `--trace-out` JSONL path. Virtual-time
    /// only — the `"obs"` block is excluded from `deterministic_json()`
    /// exactly like `"perf"`.
    pub trace: TraceConfig,

    // --- parameter-server tier ---
    /// Parameter-server tier shape (the `[ps]` TOML table; see
    /// [`crate::ps`] and `docs/parameter-server.md`): shard count,
    /// replica sets, pull coalescing and the Eq. 6 λ source. Only the
    /// centralized engines (`asgd` | `dcasgd`) read it; decentralized
    /// runs carry the defaults untouched.
    pub ps: PsConfig,

    // --- bookkeeping ---
    /// Validation pass every this many iterations (0 = only at the end).
    pub eval_every: u64,
    /// Batches per validation pass.
    pub eval_batches: usize,
    /// Output directory for CSV dumps (None = no dumps).
    pub out_dir: Option<PathBuf>,
}

/// Simulator backend knobs (the `[sim]` TOML table). Orthogonal to the
/// algorithm config: every backend yields bit-identical training
/// results, so this never appears in run JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SimConfig {
    /// Rendezvous storage/completion strategy. See [`SimBackend`].
    pub backend: SimBackend,
}

/// Trace/observability knobs (the `[trace]` TOML table; see
/// [`crate::obs`]). The event journal is a bounded ring: `capacity`
/// events per rank lane and per export, oldest dropped first (with a
/// dropped count in the `"obs"` block). `capacity = 0` disables event
/// recording entirely; the metric/window accounting stays on either
/// way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceConfig {
    /// Journal ring capacity in events (`--trace-capacity`; 0 = off).
    pub capacity: usize,
    /// Write the merged journal as JSONL here at the end of the run
    /// (`--trace-out`). Feed it to `trace-report` or
    /// `tools/trace_to_chrome.py`.
    pub out: Option<PathBuf>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { capacity: 65_536, out: None }
    }
}

/// Which λ the PS tier's Eq. 6 delay compensation uses (`dcasgd` only).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PsLambda {
    /// Eq. 17 dynamic λ from the *global* norms of g and the backup
    /// distance — the DC-S3GD spelling. Global norms couple every
    /// coordinate, so a sharded server computes per-shard λ's that
    /// differ from the unsharded trajectory (documented, not a bug).
    #[default]
    Dynamic,
    /// Per-element EWMA of g² (the SSP-ASGD adaptive-λ shape):
    /// `λ_i = λ0 / sqrt(E[g_i²] + ε)`. Fully elementwise, hence
    /// shard-invariant — the mode the sharded differential tests pin.
    Adaptive,
}

impl PsLambda {
    pub fn parse(s: &str) -> Result<PsLambda> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dynamic" => PsLambda::Dynamic,
            "adaptive" => PsLambda::Adaptive,
            other => bail!("unknown ps.lambda {other:?} (dynamic | adaptive)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PsLambda::Dynamic => "dynamic",
            PsLambda::Adaptive => "adaptive",
        }
    }
}

/// Parameter-server tier shape (the `[ps]` TOML table; see
/// [`crate::ps`]). Defaults reproduce the pre-tier server exactly:
/// one shard, single-home, dynamic λ.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PsConfig {
    /// Contiguous parameter shards, one server actor each (≥ 1).
    pub shards: usize,
    /// Replicas per shard (1 = single-home). Replicas are placement +
    /// timing only; weights stay bitwise identical to single-home.
    pub replicas: usize,
    /// Coalesce pulls that land inside an in-flight read window.
    pub coalesce: bool,
    /// Eq. 6 λ source for `dcasgd` (`dynamic` | `adaptive`).
    pub lambda: PsLambda,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig { shards: 1, replicas: 1, coalesce: true, lambda: PsLambda::Dynamic }
    }
}

impl PsConfig {
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("ps.shards must be ≥ 1");
        }
        if self.replicas == 0 {
            bail!("ps.replicas must be ≥ 1");
        }
        Ok(())
    }
}

impl ExperimentConfig {
    /// Builder seeded with the paper's defaults.
    pub fn builder(variant: &str) -> RunBuilder {
        RunBuilder { cfg: Self::defaults(variant) }
    }

    fn defaults(variant: &str) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("{variant}_run"),
            variant: variant.to_string(),
            artifacts_root: PathBuf::from("artifacts"),
            algo: Algo::DcS3gd,
            nodes: 4,
            local_batch: 32,
            steps: 200,
            seed: 0,
            optimizer: "momentum".into(),
            momentum: 0.9,
            eta_single: 0.1,
            base_batch: 256,
            warmup_frac: 0.5,
            warmup_stop_frac: 1.0 / 6.0, // 15 of 90 epochs
            weight_decay: 1e-4,
            wd_k: 2.3,
            lam0: 0.2,
            staleness: 1,
            n_train: 8192,
            n_val: 1024,
            data_noise: 0.6,
            net: NetModel::default(),
            dragonfly: Dragonfly::default(),
            compute: ComputeModel::default(),
            time_from_wall: false,
            control: ControlConfig::default(),
            compress: CompressConfig::default(),
            hetero: HeteroConfig::default(),
            perf: PerfConfig::default(),
            sim: SimConfig::default(),
            trace: TraceConfig::default(),
            ps: PsConfig::default(),
            eval_every: 0,
            eval_batches: 8,
            out_dir: None,
        }
    }

    /// Global batch |B| = N · local batch.
    pub fn global_batch(&self) -> usize {
        self.nodes * self.local_batch
    }

    /// Peak LR per the Eq. 16 linear-scaling rule.
    pub fn eta_peak(&self) -> f32 {
        crate::optim::LrSchedule::scaled_peak(self.eta_single, self.global_batch(), self.base_batch)
    }

    /// The paper's LR schedule resolved for this run.
    pub fn lr_schedule(&self) -> crate::optim::LrSchedule {
        let planned = ((self.steps as f32) * self.warmup_frac).max(1.0) as u64;
        let stop = ((self.steps as f32) * self.warmup_stop_frac) as u64;
        crate::optim::LrSchedule::paper(self.eta_peak(), planned, stop.min(planned), self.steps)
    }

    /// The dragonfly topology the hierarchical schedule runs on: the
    /// one embedded in `net.algo` when the run is already hierarchical,
    /// else the `[comm]` table's candidate topology.
    pub fn topology(&self) -> Dragonfly {
        match self.net.algo {
            AllReduceAlgo::Hierarchical(d) => d,
            _ => self.dragonfly,
        }
    }

    /// Effective weight decay at iteration `it`: same shape as the LR
    /// schedule, scaled to wd·k at the schedule's peak (§IV-A).
    pub fn wd_at(&self, it: u64, sched: &crate::optim::LrSchedule) -> f32 {
        let peak = sched.reached_peak();
        if peak <= 0.0 {
            return self.weight_decay * self.wd_k;
        }
        self.weight_decay * self.wd_k * sched.at(it) / peak
    }

    /// Load from a TOML file (see `configs/` for examples).
    pub fn from_toml_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text. Unknown keys are rejected (typo safety).
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let map = parse_toml(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_map(map)
    }

    fn from_map(map: BTreeMap<String, TomlValue>) -> Result<Self> {
        let variant = map
            .get("variant")
            .and_then(TomlValue::as_str)
            .unwrap_or("linear")
            .to_string();
        let mut cfg = Self::defaults(&variant);
        // Deprecated spellings (`net.algo`, flat `control.fault_*`)
        // are collected here and resolved at one normalization point
        // after the loop — see [`LegacyAliases::apply`].
        let mut legacy = LegacyAliases::default();
        // `[[control.fault]]` / `[[control.join]]` table-array specs.
        let mut fault_events: Vec<FaultEvent> = Vec::new();
        let mut join_events: Vec<JoinEvent> = Vec::new();
        // `[comm]` table: schedule + dragonfly shape/links, assembled
        // after the loop (the schedule may need the final topology and
        // node count).
        let mut comm_schedule: Option<String> = None;
        let mut comm_groups: Option<usize> = None;
        let mut comm_npg: Option<usize> = None;
        let mut comm_alpha_local: Option<f64> = None;
        let mut comm_beta_local: Option<f64> = None;
        let mut comm_alpha_global: Option<f64> = None;
        let mut comm_beta_global: Option<f64> = None;
        // `[comm.contention]` table: global links per group.
        let mut comm_taper: Option<usize> = None;
        for (key, val) in &map {
            let k = key.as_str();
            let err = || anyhow::anyhow!("bad value for {k}");
            match k {
                "name" => cfg.name = val.as_str().ok_or_else(err)?.to_string(),
                "variant" => {}
                "artifacts_root" => cfg.artifacts_root = val.as_str().ok_or_else(err)?.into(),
                "algo" => cfg.algo = Algo::parse(val.as_str().ok_or_else(err)?)?,
                "nodes" => cfg.nodes = val.as_i64().ok_or_else(err)? as usize,
                "local_batch" => cfg.local_batch = val.as_i64().ok_or_else(err)? as usize,
                "steps" => cfg.steps = val.as_i64().ok_or_else(err)? as u64,
                "seed" => cfg.seed = val.as_i64().ok_or_else(err)? as u64,
                "optim.kind" => cfg.optimizer = val.as_str().ok_or_else(err)?.to_string(),
                "optim.momentum" => cfg.momentum = val.as_f64().ok_or_else(err)? as f32,
                "optim.eta_single" => cfg.eta_single = val.as_f64().ok_or_else(err)? as f32,
                "optim.base_batch" => cfg.base_batch = val.as_i64().ok_or_else(err)? as usize,
                "optim.warmup_frac" => cfg.warmup_frac = val.as_f64().ok_or_else(err)? as f32,
                "optim.warmup_stop_frac" => {
                    cfg.warmup_stop_frac = val.as_f64().ok_or_else(err)? as f32
                }
                "optim.weight_decay" => cfg.weight_decay = val.as_f64().ok_or_else(err)? as f32,
                "optim.wd_k" => cfg.wd_k = val.as_f64().ok_or_else(err)? as f32,
                "optim.lam0" => cfg.lam0 = val.as_f64().ok_or_else(err)? as f32,
                "optim.staleness" => cfg.staleness = val.as_i64().ok_or_else(err)? as usize,
                "data.n_train" => cfg.n_train = val.as_i64().ok_or_else(err)? as usize,
                "data.n_val" => cfg.n_val = val.as_i64().ok_or_else(err)? as usize,
                "data.noise" => cfg.data_noise = val.as_f64().ok_or_else(err)? as f32,
                "net.alpha_s" => cfg.net.alpha_s = val.as_f64().ok_or_else(err)?,
                "net.beta_bytes_per_s" => cfg.net.beta_bytes_per_s = val.as_f64().ok_or_else(err)?,
                // deprecated spelling of the schedule; `comm.schedule` wins
                "net.algo" => {
                    legacy.net_algo = Some(val.as_str().ok_or_else(err)?.to_string())
                }
                "comm.schedule" => {
                    comm_schedule = Some(val.as_str().ok_or_else(err)?.to_string())
                }
                "comm.groups" => comm_groups = Some(val.as_i64().ok_or_else(err)? as usize),
                "comm.nodes_per_group" => {
                    comm_npg = Some(val.as_i64().ok_or_else(err)? as usize)
                }
                "comm.alpha_local_s" => comm_alpha_local = Some(val.as_f64().ok_or_else(err)?),
                "comm.beta_local" => comm_beta_local = Some(val.as_f64().ok_or_else(err)?),
                "comm.alpha_global_s" => {
                    comm_alpha_global = Some(val.as_f64().ok_or_else(err)?)
                }
                "comm.beta_global" => comm_beta_global = Some(val.as_f64().ok_or_else(err)?),
                "comm.contention.global_taper" => {
                    comm_taper = Some(val.as_i64().ok_or_else(err)? as usize)
                }
                "compute.sec_per_sample" => {
                    cfg.compute.sec_per_sample = val.as_f64().ok_or_else(err)?
                }
                "compute.overhead_s" => cfg.compute.overhead_s = val.as_f64().ok_or_else(err)?,
                "compute.jitter_frac" => cfg.compute.jitter_frac = val.as_f64().ok_or_else(err)?,
                "compute.time_from_wall" => cfg.time_from_wall = val.as_bool().ok_or_else(err)?,
                "eval.every" => cfg.eval_every = val.as_i64().ok_or_else(err)? as u64,
                "eval.batches" => cfg.eval_batches = val.as_i64().ok_or_else(err)? as usize,
                "control.policy" => {
                    cfg.control.policy = ControlPolicy::parse(val.as_str().ok_or_else(err)?)?
                }
                "control.k_min" => cfg.control.k_min = val.as_i64().ok_or_else(err)? as usize,
                "control.k_max" => cfg.control.k_max = val.as_i64().ok_or_else(err)? as usize,
                "control.gain_p" => cfg.control.gain_p = val.as_f64().ok_or_else(err)?,
                "control.gain_i" => cfg.control.gain_i = val.as_f64().ok_or_else(err)?,
                "control.adjust_every" => {
                    cfg.control.adjust_every = val.as_i64().ok_or_else(err)? as u64
                }
                "control.lam_scale_min" => {
                    cfg.control.lam_scale_min = val.as_f64().ok_or_else(err)? as f32
                }
                "control.lam_scale_max" => {
                    cfg.control.lam_scale_max = val.as_f64().ok_or_else(err)? as f32
                }
                "control.schedule_hysteresis" => {
                    cfg.control.schedule_hysteresis = val.as_f64().ok_or_else(err)?
                }
                "control.probe" => {
                    cfg.control.probe = ProbeMode::parse(val.as_str().ok_or_else(err)?)?
                }
                "control.probe_interval" => {
                    cfg.control.probe_interval = val.as_i64().ok_or_else(err)? as u64
                }
                "control.probe_epsilon" => {
                    cfg.control.probe_epsilon = val.as_f64().ok_or_else(err)?
                }
                "control.straggler_factor" => {
                    cfg.control.straggler_factor = val.as_f64().ok_or_else(err)?
                }
                "control.quarantine_after" => {
                    cfg.control.quarantine_after = val.as_i64().ok_or_else(err)? as u64
                }
                "control.heartbeat_timeout_s" => {
                    cfg.control.heartbeat_timeout_s = val.as_f64().ok_or_else(err)?
                }
                "control.restore_s" => cfg.control.restore_s = val.as_f64().ok_or_else(err)?,
                "control.snapshot_every" => {
                    cfg.control.snapshot_every = val.as_i64().ok_or_else(err)? as u64
                }
                "control.join_warmup_windows" => {
                    cfg.control.join_warmup_windows = val.as_i64().ok_or_else(err)? as u64
                }
                "compress.kind" => {
                    cfg.compress.kind = CompressorKind::parse(val.as_str().ok_or_else(err)?)?
                }
                "compress.ratio" => cfg.compress.ratio = val.as_f64().ok_or_else(err)? as f32,
                "compress.bits" => cfg.compress.bits = val.as_i64().ok_or_else(err)? as u32,
                "compress.ratio_min" => {
                    cfg.compress.ratio_min = val.as_f64().ok_or_else(err)? as f32
                }
                "compress.ratio_max" => {
                    cfg.compress.ratio_max = val.as_f64().ok_or_else(err)? as f32
                }
                "hetero.enabled" => cfg.hetero.enabled = val.as_bool().ok_or_else(err)?,
                "hetero.tiers" => cfg.hetero.tiers = parse_f64_array(val, k)?,
                "hetero.tier_weights" => cfg.hetero.tier_weights = parse_f64_array(val, k)?,
                "hetero.spot_fraction" => {
                    cfg.hetero.spot_fraction = val.as_f64().ok_or_else(err)?
                }
                "hetero.spot_mtbf_s" => cfg.hetero.spot_mtbf_s = val.as_f64().ok_or_else(err)?,
                "hetero.spot_correlation" => {
                    cfg.hetero.spot_correlation = val.as_f64().ok_or_else(err)?
                }
                "hetero.diurnal_amplitude" => {
                    cfg.hetero.diurnal_amplitude = val.as_f64().ok_or_else(err)?
                }
                "hetero.diurnal_period_s" => {
                    cfg.hetero.diurnal_period_s = val.as_f64().ok_or_else(err)?
                }
                "hetero.link_spread" => cfg.hetero.link_spread = val.as_f64().ok_or_else(err)?,
                "perf.threads" => cfg.perf.threads = val.as_i64().ok_or_else(err)? as usize,
                "perf.pin_chunk" => cfg.perf.pin_chunk = val.as_i64().ok_or_else(err)? as usize,
                "sim.backend" => {
                    let s = val.as_str().ok_or_else(err)?;
                    cfg.sim.backend = SimBackend::parse(s).ok_or_else(|| {
                        anyhow::anyhow!("unknown sim.backend {s:?} (dense | folded)")
                    })?
                }
                "trace.capacity" => {
                    cfg.trace.capacity = val.as_i64().ok_or_else(err)? as usize
                }
                "trace.out" => cfg.trace.out = Some(val.as_str().ok_or_else(err)?.into()),
                "ps.shards" => cfg.ps.shards = val.as_i64().ok_or_else(err)? as usize,
                "ps.replicas" => cfg.ps.replicas = val.as_i64().ok_or_else(err)? as usize,
                "ps.coalesce" => cfg.ps.coalesce = val.as_bool().ok_or_else(err)?,
                "ps.lambda" => {
                    cfg.ps.lambda = PsLambda::parse(val.as_str().ok_or_else(err)?)?
                }
                // deprecated flat single-fault spelling; prefer
                // `[[control.fault]]` tables.
                "control.fault_rank" => {
                    legacy.fault_rank = Some(val.as_i64().ok_or_else(err)? as usize)
                }
                "control.fault_at_s" => legacy.fault_at_s = Some(val.as_f64().ok_or_else(err)?),
                "control.fault_kind" => {
                    legacy.fault_kind = Some(val.as_str().ok_or_else(err)?.to_string())
                }
                "control.fault_factor" => legacy.fault_factor = val.as_f64().ok_or_else(err)?,
                "control.fault_duration_s" => {
                    legacy.fault_duration_s = val.as_f64().ok_or_else(err)?
                }
                "control.fault_extra_s" => legacy.fault_extra_s = val.as_f64().ok_or_else(err)?,
                "control.fault_respawn" => {
                    legacy.fault_respawn = val.as_bool().ok_or_else(err)?
                }
                // `[[control.fault]]` table array: any number of specs.
                "control.fault" => {
                    for entry in val.as_array().ok_or_else(err)? {
                        let table = entry.as_table().ok_or_else(|| {
                            anyhow::anyhow!("control.fault must be [[control.fault]] tables")
                        })?;
                        fault_events.push(parse_fault_table(table)?);
                    }
                }
                // `[[control.join]]` table array: scripted arrivals
                // (membership-epoch growth).
                "control.join" => {
                    for entry in val.as_array().ok_or_else(err)? {
                        let table = entry.as_table().ok_or_else(|| {
                            anyhow::anyhow!("control.join must be [[control.join]] tables")
                        })?;
                        join_events.extend(parse_join_table(table)?);
                    }
                }
                "out_dir" => cfg.out_dir = Some(val.as_str().ok_or_else(err)?.into()),
                other => bail!("unknown config key {other:?}"),
            }
        }
        // Assemble the `[comm]` dragonfly: an explicit shape wins, a
        // half-specified shape derives its other dimension from the
        // run's node count (a partial shape must never silently
        // collapse the hierarchy into one group), and no shape at all
        // fits the topology to the node count.
        let nodes = cfg.nodes.max(1);
        let mut d = match (comm_groups, comm_npg) {
            (None, None) => Dragonfly::for_nodes(nodes),
            (Some(g), Some(m)) => {
                Dragonfly { groups: g.max(1), nodes_per_group: m.max(1), ..Dragonfly::default() }
            }
            (Some(g), None) => {
                let g = g.max(1);
                Dragonfly {
                    groups: g,
                    nodes_per_group: nodes.div_ceil(g).max(1),
                    ..Dragonfly::default()
                }
            }
            (None, Some(m)) => {
                let m = m.max(1);
                Dragonfly {
                    groups: nodes.div_ceil(m).max(1),
                    nodes_per_group: m,
                    ..Dragonfly::default()
                }
            }
        };
        if let Some(v) = comm_alpha_local {
            d.alpha_local_s = v;
        }
        if let Some(v) = comm_beta_local {
            d.beta_local = v;
        }
        if let Some(v) = comm_alpha_global {
            d.alpha_global_s = v;
        }
        if let Some(v) = comm_beta_global {
            d.beta_global = v;
        }
        if let Some(t) = comm_taper {
            d.global_taper = t.max(1);
        }
        cfg.dragonfly = d;
        // Single normalization point for every deprecated alias. The
        // flat fault lands before the table-array specs (matching the
        // documented composition order), and the legacy schedule is
        // applied first so the explicit `comm.schedule` below wins.
        legacy.apply(&mut cfg, d)?;
        for e in fault_events {
            cfg.control.faults.push(e);
        }
        cfg.control.joins = join_events;
        if let Some(name) = comm_schedule {
            cfg.net.algo = parse_schedule(&name, d)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            bail!("nodes must be ≥ 1");
        }
        if self.local_batch == 0 {
            bail!("local_batch must be ≥ 1");
        }
        if self.staleness == 0 {
            bail!("staleness must be ≥ 1");
        }
        if !(0.0..=1.0).contains(&self.warmup_frac)
            || !(0.0..=1.0).contains(&self.warmup_stop_frac)
        {
            bail!("warmup fractions must be in [0, 1]");
        }
        if self.warmup_stop_frac > self.warmup_frac {
            bail!("warmup_stop_frac must not exceed warmup_frac");
        }
        self.control.validate()?;
        self.compress.validate()?;
        self.hetero.validate()?;
        self.perf.validate()?;
        self.ps.validate()?;
        // Spot revocations become membership departures, so they need
        // the windowed (stale-synchronous) engine family.
        if self.hetero.enabled
            && self.hetero.spot_fraction > 0.0
            && self.hetero.spot_mtbf_s > 0.0
            && !self.algo.is_windowed()
        {
            bail!(
                "hetero spot revocations depart the run and need a windowed engine \
                 (s3gd | dcs3gd | dyn_ssp | sgs), got {}",
                self.algo.name()
            );
        }
        // Membership events: joins are fresh rank ids above the initial
        // world (departed ids are retired, like replaced machines), and
        // faults may target any rank the run can ever hold.
        let membership = self.control.membership_log(self.nodes);
        let capacity = membership.capacity();
        for j in self.control.joins.iter() {
            if j.rank < self.nodes {
                bail!(
                    "control.join rank {} collides with the initial world 0..{} \
                     (join ranks must be fresh ids)",
                    j.rank,
                    self.nodes
                );
            }
        }
        if membership.is_elastic() {
            let initial_departures = membership
                .departs()
                .iter()
                .filter(|(rank, _)| *rank < self.nodes)
                .count();
            if initial_departures >= self.nodes {
                bail!("every initial rank departs — the cluster would empty out");
            }
        }
        for e in self.control.faults.events() {
            if e.rank >= capacity {
                bail!(
                    "fault targets rank {} but the run never holds more than {} ranks",
                    e.rank,
                    capacity
                );
            }
            if e.rank >= self.nodes && !membership.is_join_rank(e.rank) {
                bail!("fault targets rank {} which never joins the run", e.rank);
            }
        }
        Ok(())
    }

    /// The resolved heterogeneity profile over the run's full capacity
    /// (initial ranks + scripted joiners), or `None` when the subsystem
    /// is off. Local links are per-rank; global links per dragonfly
    /// group.
    pub fn hetero_profile(&self) -> Option<HeteroProfile> {
        if !self.hetero.enabled {
            return None;
        }
        let capacity = self.control.membership_log(self.nodes).capacity();
        Some(HeteroProfile::resolve(
            &self.hetero,
            self.seed,
            capacity,
            capacity,
            self.topology().groups,
        ))
    }

    /// Residual link-spread asymmetry a *flat* collective suffers
    /// relative to what its baked β claims:
    /// `min(link_scale_local, link_scale_global) / link_scale_local`
    /// from the resolved hetero profile. [`Self::with_hetero_applied`]
    /// scales the flat β by the local link class, but a flat schedule
    /// spanning groups crosses the global optics too and is
    /// bottlenecked by the slowest link class — the schedule-coupled
    /// candidate pricing multiplies the flat β by this factor. 1.0
    /// when hetero is off or the global class is no slower.
    pub fn flat_link_residual(&self) -> f64 {
        match self.hetero_profile() {
            Some(p) if p.link_scale_local > 0.0 => {
                (p.link_scale_local.min(p.link_scale_global) / p.link_scale_local).min(1.0)
            }
            _ => 1.0,
        }
    }

    /// A copy of this config with the heterogeneity profile merged into
    /// the base models: tier multipliers into the compute model's
    /// per-rank straggler factors, bottleneck link scales into the flat
    /// and dragonfly β's, and spot revocations into the fault plan as
    /// permanent departures. Idempotent (`hetero.applied` guards a
    /// second pass); a no-op when the subsystem is off.
    pub fn with_hetero_applied(&self) -> ExperimentConfig {
        let mut cfg = self.clone();
        if !cfg.hetero.enabled || cfg.hetero.applied {
            return cfg;
        }
        let profile = self.hetero_profile().expect("hetero enabled");
        if cfg.compute.straggler_factor.len() < profile.tier.len() {
            cfg.compute.straggler_factor.resize(profile.tier.len(), 1.0);
        }
        for (f, tier) in cfg.compute.straggler_factor.iter_mut().zip(&profile.tier) {
            *f *= tier;
        }
        cfg.net.beta_bytes_per_s *= profile.link_scale_local;
        cfg.dragonfly.beta_local *= profile.link_scale_local;
        cfg.dragonfly.beta_global *= profile.link_scale_global;
        if let AllReduceAlgo::Hierarchical(ref mut d) = cfg.net.algo {
            d.beta_local *= profile.link_scale_local;
            d.beta_global *= profile.link_scale_global;
        }
        for &(rank, at_s) in &profile.revocations {
            cfg.control.faults.push(FaultEvent {
                rank,
                at_s,
                kind: FaultKind::Kill { respawn: false },
            });
        }
        cfg.hetero.applied = true;
        cfg
    }
}

/// Deprecated config spellings, collected during the key loop and
/// resolved at exactly one place ([`LegacyAliases::apply`]) so the
/// modern keys have a single, auditable precedence story:
///
/// * `net.algo` — old name for `comm.schedule`; the explicit
///   `comm.schedule` key wins when both are present.
/// * `control.fault_rank` / `fault_at_s` / `fault_kind` /
///   `fault_factor` / `fault_duration_s` / `fault_extra_s` /
///   `fault_respawn` — flat single-fault spelling, superseded by
///   `[[control.fault]]` tables; a flat fault composes with the table
///   array and sorts before it.
///
/// See `docs/config.md` § "Deprecated aliases".
struct LegacyAliases {
    net_algo: Option<String>,
    fault_rank: Option<usize>,
    fault_at_s: Option<f64>,
    fault_kind: Option<String>,
    fault_factor: f64,
    fault_duration_s: f64,
    fault_extra_s: f64,
    fault_respawn: bool,
}

impl Default for LegacyAliases {
    fn default() -> Self {
        LegacyAliases {
            net_algo: None,
            fault_rank: None,
            fault_at_s: None,
            fault_kind: None,
            fault_factor: 2.0,
            fault_duration_s: 1.0,
            fault_extra_s: 0.5,
            fault_respawn: true,
        }
    }
}

impl LegacyAliases {
    /// Fold every collected alias into `cfg`. Called once per parse,
    /// before the explicit modern keys that supersede them are applied.
    fn apply(self, cfg: &mut ExperimentConfig, topology: Dragonfly) -> Result<()> {
        if let Some(name) = self.net_algo {
            cfg.net.algo = parse_schedule(&name, topology)?;
        }
        if let Some(kind) = self.fault_kind {
            let rank = self
                .fault_rank
                .ok_or_else(|| anyhow::anyhow!("control.fault_kind needs control.fault_rank"))?;
            let at_s = self
                .fault_at_s
                .ok_or_else(|| anyhow::anyhow!("control.fault_kind needs control.fault_at_s"))?;
            let kind = match kind.as_str() {
                "kill" => FaultKind::Kill { respawn: self.fault_respawn },
                "slow" => FaultKind::Slow {
                    factor: self.fault_factor,
                    duration_s: self.fault_duration_s,
                },
                "delay" => FaultKind::Delay { extra_s: self.fault_extra_s },
                other => bail!("unknown control.fault_kind {other:?} (kill | slow | delay)"),
            };
            cfg.control.faults.push(FaultEvent { rank, at_s, kind });
        }
        Ok(())
    }
}

/// A flat TOML array of numbers (`tiers = [1.0, 1.6, 2.5]`).
fn parse_f64_array(val: &TomlValue, key: &str) -> Result<Vec<f64>> {
    val.as_array()
        .and_then(|xs| xs.iter().map(TomlValue::as_f64).collect::<Option<Vec<f64>>>())
        .ok_or_else(|| anyhow::anyhow!("{key} must be an array of numbers"))
}

/// Parse a collective-schedule name into an [`AllReduceAlgo`];
/// `hierarchical` binds the given dragonfly topology. Shared by the
/// `[comm]` table, the legacy `net.algo` key, and the CLI `--schedule`
/// flag.
pub fn parse_schedule(name: &str, topology: Dragonfly) -> Result<AllReduceAlgo> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "ring" => AllReduceAlgo::Ring,
        "tree" => AllReduceAlgo::Tree,
        "flat" => AllReduceAlgo::Flat,
        "hierarchical" | "hier" | "layered" => AllReduceAlgo::Hierarchical(topology),
        other => bail!("unknown collective schedule {other:?} (ring | tree | flat | hierarchical)"),
    })
}

/// One `[[control.fault]]` table: `rank`, `at_s`, `kind` (required) plus
/// the kind-specific knobs (`respawn = false` turns a kill into a
/// permanent departure). Unknown keys are rejected (typo safety).
fn parse_fault_table(table: &BTreeMap<String, TomlValue>) -> Result<FaultEvent> {
    let mut rank: Option<usize> = None;
    let mut at_s: Option<f64> = None;
    let mut kind: Option<String> = None;
    let mut factor = 2.0f64;
    let mut duration_s = 1.0f64;
    let mut extra_s = 0.5f64;
    let mut respawn = true;
    for (k, v) in table {
        let err = || anyhow::anyhow!("bad value for control.fault.{k}");
        match k.as_str() {
            "rank" => rank = Some(v.as_i64().ok_or_else(err)? as usize),
            "at_s" => at_s = Some(v.as_f64().ok_or_else(err)?),
            "kind" => kind = Some(v.as_str().ok_or_else(err)?.to_string()),
            "factor" => factor = v.as_f64().ok_or_else(err)?,
            "duration_s" => duration_s = v.as_f64().ok_or_else(err)?,
            "extra_s" => extra_s = v.as_f64().ok_or_else(err)?,
            "respawn" => respawn = v.as_bool().ok_or_else(err)?,
            other => bail!("unknown [[control.fault]] key {other:?}"),
        }
    }
    let rank = rank.ok_or_else(|| anyhow::anyhow!("[[control.fault]] needs rank"))?;
    let at_s = at_s.ok_or_else(|| anyhow::anyhow!("[[control.fault]] needs at_s"))?;
    let kind = match kind.ok_or_else(|| anyhow::anyhow!("[[control.fault]] needs kind"))?.as_str()
    {
        "kill" => FaultKind::Kill { respawn },
        "slow" => FaultKind::Slow { factor, duration_s },
        "delay" => FaultKind::Delay { extra_s },
        other => bail!("unknown [[control.fault]] kind {other:?} (kill | slow | delay)"),
    };
    Ok(FaultEvent { rank, at_s, kind })
}

/// One `[[control.join]]` table: `at_s` (required) plus either a single
/// `rank` or a `first_rank` + `count` block of fresh arrivals. Unknown
/// keys are rejected (typo safety).
fn parse_join_table(table: &BTreeMap<String, TomlValue>) -> Result<Vec<JoinEvent>> {
    let mut rank: Option<usize> = None;
    let mut first_rank: Option<usize> = None;
    let mut count: Option<usize> = None;
    let mut at_s: Option<f64> = None;
    for (k, v) in table {
        let err = || anyhow::anyhow!("bad value for control.join.{k}");
        match k.as_str() {
            "rank" => rank = Some(v.as_i64().ok_or_else(err)? as usize),
            "first_rank" => first_rank = Some(v.as_i64().ok_or_else(err)? as usize),
            "count" => count = Some(v.as_i64().ok_or_else(err)? as usize),
            "at_s" => at_s = Some(v.as_f64().ok_or_else(err)?),
            other => bail!("unknown [[control.join]] key {other:?}"),
        }
    }
    let at_s = at_s.ok_or_else(|| anyhow::anyhow!("[[control.join]] needs at_s"))?;
    match (rank, first_rank) {
        (Some(r), None) => {
            if count.is_some() {
                bail!("[[control.join]] count only applies with first_rank");
            }
            Ok(vec![JoinEvent { rank: r, at_s }])
        }
        (None, Some(first)) => {
            let count = count.unwrap_or(1);
            if count == 0 {
                bail!("[[control.join]] count must be ≥ 1");
            }
            Ok((first..first + count).map(|rank| JoinEvent { rank, at_s }).collect())
        }
        (None, None) => bail!("[[control.join]] needs rank or first_rank"),
        (Some(_), Some(_)) => bail!("[[control.join]] takes rank or first_rank, not both"),
    }
}

/// Fluent builder over [`ExperimentConfig`] — the single programmatic
/// entry point for constructing and launching runs. Every example,
/// bench, and test goes through `ExperimentConfig::builder(..)` and
/// either [`RunBuilder::build`] (for the config alone) or
/// [`RunBuilder::run`] (build + execute through the engine registry).
pub struct RunBuilder {
    cfg: ExperimentConfig,
}

/// Old name of [`RunBuilder`], kept as a deprecated alias.
#[deprecated(note = "renamed to RunBuilder")]
pub type ConfigBuilder = RunBuilder;

impl RunBuilder {
    pub fn name(mut self, v: &str) -> Self {
        self.cfg.name = v.into();
        self
    }
    pub fn algo(mut self, v: Algo) -> Self {
        self.cfg.algo = v;
        self
    }
    pub fn nodes(mut self, v: usize) -> Self {
        self.cfg.nodes = v;
        self
    }
    pub fn local_batch(mut self, v: usize) -> Self {
        self.cfg.local_batch = v;
        self
    }
    pub fn steps(mut self, v: u64) -> Self {
        self.cfg.steps = v;
        self
    }
    pub fn seed(mut self, v: u64) -> Self {
        self.cfg.seed = v;
        self
    }
    pub fn eta_single(mut self, v: f32) -> Self {
        self.cfg.eta_single = v;
        self
    }
    pub fn base_batch(mut self, v: usize) -> Self {
        self.cfg.base_batch = v;
        self
    }
    pub fn momentum(mut self, v: f32) -> Self {
        self.cfg.momentum = v;
        self
    }
    pub fn lam0(mut self, v: f32) -> Self {
        self.cfg.lam0 = v;
        self
    }
    pub fn staleness(mut self, v: usize) -> Self {
        self.cfg.staleness = v;
        self
    }
    pub fn optimizer(mut self, v: &str) -> Self {
        self.cfg.optimizer = v.into();
        self
    }
    pub fn weight_decay(mut self, v: f32) -> Self {
        self.cfg.weight_decay = v;
        self
    }
    pub fn warmup(mut self, planned_frac: f32, stop_frac: f32) -> Self {
        self.cfg.warmup_frac = planned_frac;
        self.cfg.warmup_stop_frac = stop_frac;
        self
    }
    pub fn net(mut self, v: NetModel) -> Self {
        self.cfg.net = v;
        self
    }
    /// Set the `[comm]` dragonfly (the hierarchical-schedule topology).
    pub fn dragonfly(mut self, v: Dragonfly) -> Self {
        self.cfg.dragonfly = v;
        self
    }
    /// Run the collectives on an explicit schedule by name
    /// (`ring | tree | flat | hierarchical`), binding the builder's
    /// dragonfly for the hierarchical case.
    pub fn schedule(mut self, name: &str) -> Self {
        self.cfg.net.algo =
            parse_schedule(name, self.cfg.dragonfly).expect("invalid schedule name");
        self
    }
    pub fn compute(mut self, v: ComputeModel) -> Self {
        self.cfg.compute = v;
        self
    }
    pub fn time_from_wall(mut self, v: bool) -> Self {
        self.cfg.time_from_wall = v;
        self
    }
    pub fn data(mut self, n_train: usize, n_val: usize, noise: f32) -> Self {
        self.cfg.n_train = n_train;
        self.cfg.n_val = n_val;
        self.cfg.data_noise = noise;
        self
    }
    pub fn eval_every(mut self, every: u64, batches: usize) -> Self {
        self.cfg.eval_every = every;
        self.cfg.eval_batches = batches;
        self
    }
    pub fn out_dir(mut self, v: impl Into<PathBuf>) -> Self {
        self.cfg.out_dir = Some(v.into());
        self
    }
    /// Replace the whole `[control]` table.
    pub fn control(mut self, v: ControlConfig) -> Self {
        self.cfg.control = v;
        self
    }
    pub fn control_policy(mut self, v: ControlPolicy) -> Self {
        self.cfg.control.policy = v;
        self
    }
    pub fn k_bounds(mut self, k_min: usize, k_max: usize) -> Self {
        self.cfg.control.k_min = k_min;
        self.cfg.control.k_max = k_max;
        self
    }
    pub fn faults(mut self, v: FaultPlan) -> Self {
        self.cfg.control.faults = v;
        self
    }
    /// Script a membership arrival: fresh `rank` joins at `at_s`.
    pub fn join(mut self, rank: usize, at_s: f64) -> Self {
        self.cfg.control.joins.push(JoinEvent { rank, at_s });
        self
    }
    /// Joiner LR warm-up length, in windows (0 = no ramp).
    pub fn join_warmup(mut self, windows: u64) -> Self {
        self.cfg.control.join_warmup_windows = windows;
        self
    }
    /// Replace the whole `[compress]` table.
    pub fn compress(mut self, v: CompressConfig) -> Self {
        self.cfg.compress = v;
        self
    }
    /// Replace the whole `[hetero]` table.
    pub fn hetero(mut self, v: HeteroConfig) -> Self {
        self.cfg.hetero = v;
        self
    }
    /// Engine worker-pool thread budget (`0` = auto, `1` = serial).
    pub fn threads(mut self, v: usize) -> Self {
        self.cfg.perf.threads = v;
        self
    }
    /// Vectorized-kernel chunk width (`0` = default; power of two).
    pub fn pin_chunk(mut self, v: usize) -> Self {
        self.cfg.perf.pin_chunk = v;
        self
    }
    /// Error-feedback top-k compression at the given density.
    pub fn compress_topk(mut self, ratio: f32) -> Self {
        self.cfg.compress.kind = CompressorKind::TopK;
        self.cfg.compress.ratio = ratio;
        self
    }
    /// QSGD stochastic quantization at the given bit width.
    pub fn compress_qsgd(mut self, bits: u32) -> Self {
        self.cfg.compress.kind = CompressorKind::Qsgd;
        self.cfg.compress.bits = bits;
        self
    }
    pub fn artifacts_root(mut self, v: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_root = v.into();
        self
    }
    /// Rendezvous backend: [`SimBackend::Dense`] materializes every
    /// rank, [`SimBackend::Folded`] stores posters only. Bit-identical
    /// results either way.
    pub fn backend(mut self, v: SimBackend) -> Self {
        self.cfg.sim.backend = v;
        self
    }
    /// Obs journal ring capacity in events (`0` disables tracing).
    pub fn trace_capacity(mut self, v: usize) -> Self {
        self.cfg.trace.capacity = v;
        self
    }
    /// Write the merged JSONL trace here at the end of the run.
    pub fn trace_out(mut self, v: impl Into<PathBuf>) -> Self {
        self.cfg.trace.out = Some(v.into());
        self
    }
    /// Replace the whole `[ps]` table.
    pub fn ps(mut self, v: PsConfig) -> Self {
        self.cfg.ps = v;
        self
    }
    /// Parameter-server shard count (contiguous slices, ≥ 1).
    pub fn ps_shards(mut self, v: usize) -> Self {
        self.cfg.ps.shards = v;
        self
    }
    /// Replicas per PS shard (1 = single-home).
    pub fn ps_replicas(mut self, v: usize) -> Self {
        self.cfg.ps.replicas = v;
        self
    }
    /// Coalesce PS pulls that land inside an in-flight read window.
    pub fn ps_coalesce(mut self, v: bool) -> Self {
        self.cfg.ps.coalesce = v;
        self
    }
    /// Eq. 6 λ source for the `dcasgd` tier (`dynamic` | `adaptive`).
    pub fn ps_lambda(mut self, name: &str) -> Self {
        self.cfg.ps.lambda = PsLambda::parse(name).expect("invalid ps.lambda");
        self
    }

    pub fn build(self) -> ExperimentConfig {
        self.cfg.validate().expect("invalid config");
        self.cfg
    }

    /// Build the config and execute the run through the engine
    /// registry — the one-stop entry point that replaces the
    /// build-then-`run_experiment` two-step.
    pub fn run(self) -> Result<crate::algo::RunReport> {
        crate::algo::run_experiment(&self.build())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_match_paper() {
        let cfg = ExperimentConfig::builder("linear").build();
        assert_eq!(cfg.momentum, 0.9);
        assert_eq!(cfg.weight_decay, 1e-4);
        assert_eq!(cfg.wd_k, 2.3);
        assert_eq!(cfg.lam0, 0.2);
        assert_eq!(cfg.staleness, 1);
        assert_eq!(cfg.base_batch, 256);
    }

    #[test]
    fn eq16_global_batch_scaling() {
        let cfg = ExperimentConfig::builder("linear")
            .nodes(8)
            .local_batch(64)
            .eta_single(0.1)
            .build();
        assert_eq!(cfg.global_batch(), 512);
        assert!((cfg.eta_peak() - 0.2).abs() < 1e-6);
    }

    #[test]
    fn toml_roundtrip() {
        let doc = r#"
            name = "paper_row3"
            variant = "linear"
            algo = "dcs3gd"
            nodes = 8
            local_batch = 64
            steps = 500

            [optim]
            momentum = 0.85
            lam0 = 0.3
            staleness = 2

            [net]
            alpha_s = 2e-6
            algo = "tree"

            [eval]
            every = 50
            batches = 4
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.name, "paper_row3");
        assert_eq!(cfg.nodes, 8);
        assert_eq!(cfg.momentum, 0.85);
        assert_eq!(cfg.lam0, 0.3);
        assert_eq!(cfg.staleness, 2);
        assert_eq!(cfg.net.algo, AllReduceAlgo::Tree);
        assert_eq!(cfg.eval_every, 50);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(ExperimentConfig::from_toml_str("typo_key = 1").is_err());
    }

    #[test]
    fn comm_table_configures_hierarchical_schedule() {
        let doc = r#"
            nodes = 8

            [comm]
            schedule = "hierarchical"
            groups = 2
            nodes_per_group = 4
            beta_global = 2.5e9
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        match cfg.net.algo {
            AllReduceAlgo::Hierarchical(d) => {
                assert_eq!(d.groups, 2);
                assert_eq!(d.nodes_per_group, 4);
                assert_eq!(d.beta_global, 2.5e9);
                // unset link params keep their Aries-like defaults
                assert_eq!(d.beta_local, crate::comm::Dragonfly::default().beta_local);
            }
            other => panic!("expected hierarchical, got {other:?}"),
        }
        assert_eq!(cfg.topology().groups, 2);
    }

    #[test]
    fn partial_comm_shape_derives_the_other_dimension() {
        // Regression: `groups` alone used to keep the default 32-wide
        // groups, collapsing an 8-rank "hierarchy" into one group.
        let doc = "
            nodes = 8

            [comm]
            schedule = \"hierarchical\"
            groups = 2
        ";
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        let d = cfg.topology();
        assert_eq!(d.groups, 2);
        assert_eq!(d.nodes_per_group, 4, "must derive from the node count");
        assert!(d.groups_spanned(8) >= 2, "hierarchy collapsed");
        // and the mirror case: nodes_per_group alone derives groups
        let doc = "
            nodes = 9

            [comm]
            nodes_per_group = 3
        ";
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.dragonfly.groups, 3);
        assert_eq!(cfg.dragonfly.nodes_per_group, 3);
    }

    #[test]
    fn comm_schedule_without_shape_fits_the_node_count() {
        let doc = "
            nodes = 100

            [comm]
            schedule = \"hierarchical\"
        ";
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert!(cfg.topology().n_nodes() >= 100);
        // bad names rejected
        assert!(ExperimentConfig::from_toml_str("[comm]\nschedule = \"mesh\"").is_err());
    }

    #[test]
    fn legacy_net_algo_spelling_still_works() {
        let cfg = ExperimentConfig::from_toml_str("[net]\nalgo = \"tree\"").unwrap();
        assert_eq!(cfg.net.algo, AllReduceAlgo::Tree);
        // and it now accepts hierarchical too
        let cfg = ExperimentConfig::from_toml_str("nodes = 16\n[net]\nalgo = \"hierarchical\"")
            .unwrap();
        assert!(matches!(cfg.net.algo, AllReduceAlgo::Hierarchical(_)));
    }

    #[test]
    fn explicit_comm_schedule_wins_over_legacy_net_algo() {
        let doc = r#"
            nodes = 8

            [net]
            algo = "tree"

            [comm]
            schedule = "ring"
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.net.algo, AllReduceAlgo::Ring);
    }

    #[test]
    fn sim_backend_knob_parses_and_defaults_dense() {
        let cfg = ExperimentConfig::from_toml_str("nodes = 4").unwrap();
        assert_eq!(cfg.sim.backend, SimBackend::Dense);
        let cfg = ExperimentConfig::from_toml_str("nodes = 4\n[sim]\nbackend = \"folded\"")
            .unwrap();
        assert_eq!(cfg.sim.backend, SimBackend::Folded);
        let cfg = ExperimentConfig::from_toml_str("nodes = 4\n[sim]\nbackend = \"dense\"")
            .unwrap();
        assert_eq!(cfg.sim.backend, SimBackend::Dense);
        assert!(ExperimentConfig::from_toml_str("[sim]\nbackend = \"sparse\"").is_err());
    }

    #[test]
    fn builder_sets_the_sim_backend() {
        let cfg = ExperimentConfig::builder("linear").backend(SimBackend::Folded).build();
        assert_eq!(cfg.sim.backend, SimBackend::Folded);
        assert_eq!(ExperimentConfig::builder("linear").build().sim.backend, SimBackend::Dense);
    }

    #[test]
    fn trace_knobs_parse_and_default() {
        let cfg = ExperimentConfig::from_toml_str("nodes = 4").unwrap();
        assert_eq!(cfg.trace.capacity, 65_536);
        assert!(cfg.trace.out.is_none());
        let cfg = ExperimentConfig::from_toml_str(
            "nodes = 4\n[trace]\ncapacity = 128\nout = \"runs/t.jsonl\"",
        )
        .unwrap();
        assert_eq!(cfg.trace.capacity, 128);
        assert_eq!(cfg.trace.out, Some(PathBuf::from("runs/t.jsonl")));
    }

    #[test]
    fn builder_sets_the_trace_knobs() {
        let cfg = ExperimentConfig::builder("linear")
            .trace_capacity(0)
            .trace_out("t.jsonl")
            .build();
        assert_eq!(cfg.trace.capacity, 0);
        assert_eq!(cfg.trace.out, Some(PathBuf::from("t.jsonl")));
    }

    #[test]
    fn fault_table_array_parses_multiple_specs() {
        let doc = r#"
            nodes = 4

            [control]
            policy = "dss_pid"

            [[control.fault]]
            rank = 0
            at_s = 1.0
            kind = "kill"

            [[control.fault]]
            rank = 2
            at_s = 0.5
            kind = "slow"
            factor = 3.0
            duration_s = 2.0

            [[control.fault]]
            rank = 1
            at_s = 2.0
            kind = "delay"
            extra_s = 0.1
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        let faults = cfg.control.faults.events();
        assert_eq!(faults.len(), 3);
        assert!(cfg.control.faults.has_kills());
        assert_eq!(faults[1].rank, 2);
        assert_eq!(faults[1].kind, FaultKind::Slow { factor: 3.0, duration_s: 2.0 });
        assert_eq!(faults[2].kind, FaultKind::Delay { extra_s: 0.1 });
    }

    #[test]
    fn fault_table_array_composes_with_flat_spelling() {
        let doc = r#"
            nodes = 4

            [control]
            fault_kind = "kill"
            fault_rank = 3
            fault_at_s = 1.5

            [[control.fault]]
            rank = 1
            at_s = 0.5
            kind = "delay"
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.control.faults.events().len(), 2);
    }

    #[test]
    fn fault_table_array_rejects_bad_specs() {
        // missing required keys
        assert!(ExperimentConfig::from_toml_str("[[control.fault]]\nrank = 0").is_err());
        // unknown inner key
        assert!(ExperimentConfig::from_toml_str(
            "[[control.fault]]\nrank = 0\nat_s = 1.0\nkind = \"kill\"\ntypo = 1"
        )
        .is_err());
        // out-of-range rank caught by validate
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\n[[control.fault]]\nrank = 7\nat_s = 1.0\nkind = \"kill\""
        )
        .is_err());
    }

    #[test]
    fn comm_contention_table_parses_and_binds_the_taper() {
        let doc = r#"
            nodes = 8

            [comm]
            schedule = "hierarchical"
            groups = 2
            nodes_per_group = 4

            [comm.contention]
            global_taper = 1
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.dragonfly.global_taper, 1);
        match cfg.net.algo {
            AllReduceAlgo::Hierarchical(d) => assert_eq!(d.global_taper, 1),
            other => panic!("expected hierarchical, got {other:?}"),
        }
        // unset taper keeps the dedicated default
        let plain = ExperimentConfig::from_toml_str("nodes = 8").unwrap();
        assert_eq!(plain.dragonfly.global_taper, crate::comm::Dragonfly::default().global_taper);
        // degenerate taper clamps to 1 instead of dividing by zero
        let z = ExperimentConfig::from_toml_str("[comm.contention]\nglobal_taper = 0").unwrap();
        assert_eq!(z.dragonfly.global_taper, 1);
    }

    #[test]
    fn control_probe_knobs_parse() {
        let doc = r#"
            [control]
            policy = "schedule_coupled"
            probe = "interval"
            probe_interval = 5
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.control.probe, ProbeMode::Interval);
        assert_eq!(cfg.control.probe_interval, 5);
        let bandit = ExperimentConfig::from_toml_str(
            "[control]\nprobe = \"bandit\"\nprobe_epsilon = 0.25",
        )
        .unwrap();
        assert_eq!(bandit.control.probe, ProbeMode::Bandit);
        assert_eq!(bandit.control.probe_epsilon, 0.25);
        // bad values rejected
        assert!(ExperimentConfig::from_toml_str("[control]\nprobe = \"sometimes\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[control]\nprobe_interval = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[control]\nprobe_epsilon = 2.0").is_err());
    }

    #[test]
    fn control_schedule_knobs_parse() {
        let doc = r#"
            [control]
            policy = "schedule_coupled"
            schedule_hysteresis = 0.2
            straggler_factor = 2.0
            quarantine_after = 5
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.control.policy, ControlPolicy::ScheduleCoupled);
        assert_eq!(cfg.control.schedule_hysteresis, 0.2);
        assert_eq!(cfg.control.straggler_factor, 2.0);
        assert_eq!(cfg.control.quarantine_after, 5);
    }

    #[test]
    fn compress_table_parses() {
        let doc = r#"
            nodes = 4

            [compress]
            kind = "topk"
            ratio = 0.02
            ratio_min = 0.001
            ratio_max = 0.5

            [control]
            policy = "compress_coupled"
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.compress.kind, CompressorKind::TopK);
        assert_eq!(cfg.compress.ratio, 0.02);
        assert_eq!(cfg.compress.ratio_min, 0.001);
        assert_eq!(cfg.compress.ratio_max, 0.5);
        assert_eq!(cfg.control.policy, ControlPolicy::CompressCoupled);
        let qdoc = "[compress]\nkind = \"qsgd\"\nbits = 4";
        let qcfg = ExperimentConfig::from_toml_str(qdoc).unwrap();
        assert_eq!(qcfg.compress.kind, CompressorKind::Qsgd);
        assert_eq!(qcfg.compress.bits, 4);
    }

    #[test]
    fn bad_compress_configs_rejected() {
        // ratio out of range
        assert!(ExperimentConfig::from_toml_str("[compress]\nkind = \"topk\"\nratio = 1.5")
            .is_err());
        // bits out of range
        assert!(
            ExperimentConfig::from_toml_str("[compress]\nkind = \"qsgd\"\nbits = 1").is_err()
        );
        // unknown kind
        assert!(ExperimentConfig::from_toml_str("[compress]\nkind = \"zip\"").is_err());
        // compression needs a decentralized engine
        assert!(ExperimentConfig::from_toml_str(
            "algo = \"asgd\"\n[compress]\nkind = \"topk\""
        )
        .is_err());
        // dense kind composes with any engine
        ExperimentConfig::from_toml_str("algo = \"asgd\"\n[compress]\nkind = \"none\"").unwrap();
    }

    #[test]
    fn join_warmup_parses_and_builds() {
        let doc = r#"
            nodes = 2

            [control]
            join_warmup_windows = 6

            [[control.join]]
            rank = 2
            at_s = 1.0
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.control.join_warmup_windows, 6);
        let built = ExperimentConfig::builder("linear")
            .nodes(2)
            .join(2, 1.0)
            .join_warmup(3)
            .compress_topk(0.1)
            .build();
        assert_eq!(built.control.join_warmup_windows, 3);
        assert_eq!(built.compress.kind, CompressorKind::TopK);
    }

    #[test]
    fn control_table_parses() {
        let doc = r#"
            nodes = 4

            [control]
            policy = "lambda_coupled"
            k_min = 1
            k_max = 6
            gain_p = 0.4
            adjust_every = 2
            snapshot_every = 5
            heartbeat_timeout_s = 0.25
            fault_kind = "kill"
            fault_rank = 2
            fault_at_s = 1.5
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.control.policy, ControlPolicy::LambdaCoupled);
        assert_eq!(cfg.control.k_max, 6);
        assert_eq!(cfg.control.adjust_every, 2);
        assert_eq!(cfg.control.snapshot_every, 5);
        assert_eq!(cfg.control.heartbeat_timeout_s, 0.25);
        let faults = cfg.control.faults.events();
        assert_eq!(faults.len(), 1);
        assert_eq!(faults[0].rank, 2);
        assert_eq!(faults[0].kind, FaultKind::Kill { respawn: true });
    }

    #[test]
    fn membership_events_parse_and_validate() {
        let doc = r#"
            nodes = 4

            [[control.fault]]
            rank = 3
            at_s = 1.0
            kind = "kill"
            respawn = false

            [[control.join]]
            rank = 4
            at_s = 2.0

            [[control.join]]
            first_rank = 5
            count = 2
            at_s = 3.0
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert!(cfg.control.faults.has_departures());
        assert_eq!(cfg.control.joins.len(), 3);
        assert_eq!(cfg.control.joins[1], JoinEvent { rank: 5, at_s: 3.0 });
        let log = cfg.control.membership_log(cfg.nodes);
        assert!(log.is_elastic());
        assert_eq!(log.capacity(), 7);
        // a fault may target a join rank (join then depart)
        let doc2 = r#"
            nodes = 2

            [[control.join]]
            rank = 2
            at_s = 1.0

            [[control.fault]]
            rank = 2
            at_s = 2.0
            kind = "kill"
            respawn = false
        "#;
        ExperimentConfig::from_toml_str(doc2).unwrap();
    }

    #[test]
    fn bad_membership_configs_rejected() {
        // join rank colliding with the initial world
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 4\n[[control.join]]\nrank = 2\nat_s = 1.0"
        )
        .is_err());
        // duplicate join rank
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\n[[control.join]]\nrank = 2\nat_s = 1.0\n\
             [[control.join]]\nrank = 2\nat_s = 2.0"
        )
        .is_err());
        // every engine family handles membership events now — the old
        // windowed-only gate is gone (ssgd + the PS tier run epoch
        // transitions since the parameter-server parity PR)
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\nalgo = \"ssgd\"\n[[control.join]]\nrank = 2\nat_s = 1.0"
        )
        .is_ok());
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\nalgo = \"asgd\"\n[[control.join]]\nrank = 2\nat_s = 1.0"
        )
        .is_ok());
        // the whole initial world departing is rejected
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\n\
             [[control.fault]]\nrank = 0\nat_s = 1.0\nkind = \"kill\"\nrespawn = false\n\
             [[control.fault]]\nrank = 1\nat_s = 1.0\nkind = \"kill\"\nrespawn = false"
        )
        .is_err());
        // a fault on a rank that never exists
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\n[[control.fault]]\nrank = 5\nat_s = 1.0\nkind = \"kill\""
        )
        .is_err());
        // join needs exactly one addressing mode
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\n[[control.join]]\nrank = 2\nfirst_rank = 3\nat_s = 1.0"
        )
        .is_err());
        // count composes with first_rank only (a silently-ignored count
        // would under-deliver arrivals)
        assert!(ExperimentConfig::from_toml_str(
            "nodes = 2\n[[control.join]]\nrank = 2\ncount = 3\nat_s = 1.0"
        )
        .is_err());
    }

    #[test]
    fn ps_table_parses_and_validates() {
        let doc = r#"
            nodes = 4
            algo = "dcasgd"

            [ps]
            shards = 4
            replicas = 2
            coalesce = false
            lambda = "adaptive"

            [compress]
            kind = "topk"
            ratio = 0.1
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.ps.shards, 4);
        assert_eq!(cfg.ps.replicas, 2);
        assert!(!cfg.ps.coalesce);
        assert_eq!(cfg.ps.lambda, PsLambda::Adaptive);
        // compression is no longer decentralized-only: it rides the
        // PS tier's push/pull wire too
        assert_eq!(cfg.compress.kind, CompressorKind::TopK);
        // defaults reproduce the pre-tier server
        let plain = ExperimentConfig::from_toml_str("nodes = 2").unwrap();
        assert_eq!(plain.ps, PsConfig::default());
        assert_eq!(plain.ps.shards, 1);
        assert_eq!(plain.ps.lambda, PsLambda::Dynamic);
        // bad knobs rejected through the same validate path
        assert!(ExperimentConfig::from_toml_str("[ps]\nshards = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[ps]\nreplicas = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[ps]\nlambda = \"fixed\"").is_err());
    }

    #[test]
    fn control_fault_requires_rank_and_time() {
        let doc = "
            [control]
            fault_kind = \"kill\"
        ";
        assert!(ExperimentConfig::from_toml_str(doc).is_err());
    }

    #[test]
    fn fault_rank_out_of_range_rejected() {
        let doc = r#"
            nodes = 2

            [control]
            fault_kind = "slow"
            fault_rank = 5
            fault_at_s = 1.0
        "#;
        assert!(ExperimentConfig::from_toml_str(doc).is_err());
    }

    #[test]
    fn control_builder_hooks() {
        let cfg = ExperimentConfig::builder("linear")
            .nodes(4)
            .control_policy(ControlPolicy::DssPid)
            .k_bounds(1, 4)
            .faults(FaultPlan::new().slow(1, 0.5, 2.0, 1.0))
            .build();
        assert_eq!(cfg.control.policy, ControlPolicy::DssPid);
        assert_eq!(cfg.control.k_max, 4);
        assert_eq!(cfg.control.faults.events().len(), 1);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(ExperimentConfig::from_toml_str("nodes = 0").is_err());
        let doc = "
            [optim]
            warmup_frac = 0.1
            warmup_stop_frac = 0.5
        ";
        assert!(ExperimentConfig::from_toml_str(doc).is_err());
    }

    #[test]
    fn hetero_table_parses_and_validates() {
        let doc = r#"
            nodes = 4

            [hetero]
            enabled = true
            tiers = [1.0, 1.6, 2.5]
            tier_weights = [0.5, 0.3, 0.2]
            spot_fraction = 0.5
            spot_mtbf_s = 40.0
            spot_correlation = 0.7
            diurnal_amplitude = 0.25
            diurnal_period_s = 120.0
            link_spread = 0.4
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert!(cfg.hetero.enabled);
        assert_eq!(cfg.hetero.tiers, vec![1.0, 1.6, 2.5]);
        assert_eq!(cfg.hetero.spot_mtbf_s, 40.0);
        assert_eq!(cfg.hetero.link_spread, 0.4);
        // bad knobs rejected through the same validate path
        assert!(ExperimentConfig::from_toml_str("[hetero]\ntiers = [0.0]").is_err());
        assert!(ExperimentConfig::from_toml_str("[hetero]\ntiers = \"fast\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[hetero]\nspot_fraction = 2.0").is_err());
        // spot revocations need a windowed engine
        assert!(ExperimentConfig::from_toml_str(
            "algo = \"ssgd\"\n[hetero]\nenabled = true\nspot_fraction = 0.5\nspot_mtbf_s = 10.0"
        )
        .is_err());
    }

    #[test]
    fn with_hetero_applied_merges_the_profile_once() {
        let doc = r#"
            nodes = 6
            seed = 9

            [hetero]
            enabled = true
            tiers = [1.0, 2.0]
            spot_fraction = 1.0
            spot_mtbf_s = 5.0
            link_spread = 0.5
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        let applied = cfg.with_hetero_applied();
        assert!(applied.hetero.applied);
        let profile = cfg.hetero_profile().unwrap();
        // tiers landed in the per-rank straggler factors
        assert_eq!(applied.compute.straggler_factor, profile.tier);
        // link bottleneck scaled the flat β down
        assert!(applied.net.beta_bytes_per_s < cfg.net.beta_bytes_per_s);
        assert!(
            (applied.net.beta_bytes_per_s
                - cfg.net.beta_bytes_per_s * profile.link_scale_local)
                .abs()
                < 1e-6
        );
        // every non-anchor rank revokes (fraction 1) as a departure
        assert_eq!(profile.revocations.len(), 5);
        assert!(applied.control.faults.has_departures());
        // idempotent: a second application changes nothing
        let twice = applied.with_hetero_applied();
        assert_eq!(twice.control.faults.events().len(), applied.control.faults.events().len());
        assert_eq!(twice.compute.straggler_factor, applied.compute.straggler_factor);
        // disabled subsystem is a no-op
        let plain = ExperimentConfig::from_toml_str("nodes = 4").unwrap();
        assert!(plain.hetero_profile().is_none());
        assert!(plain.with_hetero_applied().compute.straggler_factor.is_empty());
    }

    #[test]
    fn new_engines_parse_and_admit_the_full_stack() {
        let doc = r#"
            nodes = 4
            algo = "dyn_ssp"

            [control]
            policy = "compress_coupled"

            [compress]
            kind = "topk"
            ratio = 0.1

            [[control.join]]
            rank = 4
            at_s = 2.0
        "#;
        let cfg = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(cfg.algo, Algo::DynSsp);
        // sgs too, and the dyn_ssp *policy* under the dcs3gd engine
        ExperimentConfig::from_toml_str("nodes = 2\nalgo = \"sgs\"").unwrap();
        let p = ExperimentConfig::from_toml_str("[control]\npolicy = \"dyn_ssp\"").unwrap();
        assert_eq!(p.control.policy, ControlPolicy::DynSsp);
    }

    #[test]
    fn wd_schedule_follows_lr_shape() {
        let cfg = ExperimentConfig::builder("linear").steps(100).build();
        let sched = cfg.lr_schedule();
        // ratio wd(it)/lr(it) constant in the decay phase
        let r1 = cfg.wd_at(50, &sched) / sched.at(50);
        let r2 = cfg.wd_at(80, &sched) / sched.at(80);
        assert!((r1 - r2).abs() < 1e-6 * r1.abs());
        // and equals wd·k at the reached peak
        let stop = (100.0 * cfg.warmup_stop_frac) as u64;
        let at_stop = cfg.wd_at(stop, &sched);
        assert!((at_stop - cfg.weight_decay * cfg.wd_k).abs() / at_stop < 0.05);
    }
}
