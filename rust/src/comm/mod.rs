//! Decentralized communication substrate — the simulated-MPI layer.
//!
//! The paper's algorithm needs exactly two things from MPI:
//! `MPI_Iallreduce` (non-blocking sum across all workers) and
//! `MPI_Wait`. This module provides them for N in-process workers with
//! **collective semantics identical to MPI** (every rank contributes
//! once per round, every rank receives the full payload, rounds
//! complete in sequence order) and **timing from pluggable collective
//! schedules** parameterised to Aries-like numbers (DESIGN.md §3
//! substitution table).
//!
//! Three layers:
//!
//! * [`schedule`] — the [`CollectiveSchedule`] trait and its four
//!   implementations (`Ring`, `Tree`, `FlatStar`,
//!   `Hierarchical { topology: Dragonfly }`). Every collective the
//!   substrate completes is costed by a schedule object, which
//!   decomposes its time into intra-group vs inter-group phases
//!   ([`PhaseTimes`]) — the split the control plane steers on.
//! * [`Group`] / [`Comm`] — the rendezvous-based collectives the
//!   training engines use. Data movement is exact; the reduction is
//!   performed once, in rank order, so the sum is bit-deterministic
//!   **and bit-identical across schedules** (schedules decide routing
//!   and cost, never the arithmetic). Completion *time* comes from the
//!   round's schedule, carried on the worker's virtual clock
//!   ([`crate::simtime`]); non-blocking handles capture the post time,
//!   so overlap accounting reproduces Eq. 14's `max(t_C, t_AR)`
//!   exactly. A round's schedule can be overridden per post
//!   ([`Comm::iallreduce_sched`]) — the hook the elastic control
//!   plane's `schedule_coupled` policy uses to re-pick the collective
//!   per window.
//! * [`ring`] / [`hier`] — wire-level executors (real per-edge
//!   channels): the flat ring all-reduce and the grouped
//!   Layered-SGD schedule (intra-group ring, leader ring, local
//!   broadcast). They are the differential checks that the modelled
//!   schedules correspond to real decentralized data movement, and
//!   they feed `benches/allreduce.rs`.
//!
//! ## Membership epochs (elastic cluster membership)
//!
//! A [`Group`] is no longer pinned to its launch-time world size. The
//! roster tracks, per rank, the first round sequence it participates in
//! (`admit_seq`) and the first it will never post (`depart_seq`), so
//! the **expected contributor set of every round is a pure function of
//! the round's sequence number** — deterministic regardless of
//! wall-clock thread interleaving:
//!
//! * A rank that dies without respawn calls [`Comm::leave`], which
//!   pins its `depart_seq` to its own next sequence number (everything
//!   below it was already posted) and resolves any in-flight round the
//!   rank will never contribute to **over the surviving ranks** — the
//!   payload is the survivor-set sum, and the consumer re-weights the
//!   mean by [`RoundOutcome::contributors`], keeping the gradient mean
//!   unbiased.
//! * Survivors observe the shrink from the [`RoundOutcome`] of their
//!   next wait, agree on the new epoch (every rank computes the same
//!   transition from the same round result), and call
//!   [`Comm::advance_epoch`] — idempotent, first caller applies —
//!   admitting any scripted joiners *after* the epoch's resync round.
//! * Joiners block in [`Group::await_admission`] until the survivors
//!   publish the epoch's [`JoinBootstrap`] (the canonical averaged
//!   weights + resume counters via [`Comm::publish_bootstrap`]), so
//!   every member of the new epoch starts from bit-identical state.
//!
//! A group with no membership events behaves exactly as before: all
//! ranks admitted at sequence 0, nobody departs, every round expects
//! the full world.

pub mod collectives;
pub mod event;
pub mod hier;
pub mod ring;
pub mod schedule;
pub mod topology;

pub use schedule::{CollectiveSchedule, Link, PhaseTimes, LEADER_RING_FLOWS};
pub use topology::{Dragonfly, GlobalContention};

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};

use crate::exec::Gate;

/// All-reduce schedule whose cost model [`NetModel`] applies.
///
/// This is the *config-level* description (small, `Copy`, lives in
/// [`NetModel`]); [`NetModel::schedule`] resolves it to the
/// [`CollectiveSchedule`] object that owns the cost formulas.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AllReduceAlgo {
    /// Ring: 2(N−1) steps of n/N elements — bandwidth-optimal, the
    /// algorithm Cray-mpich uses for large payloads.
    Ring,
    /// Binary tree reduce + broadcast: 2·⌈log2 N⌉ full-payload hops.
    Tree,
    /// Flat gather+scatter through rank 0 (the degenerate PS-like
    /// pattern; included for the centralised-vs-decentralised ablation).
    Flat,
    /// Hierarchical Layered-SGD schedule over a dragonfly: intra-group
    /// ring on local links, leader ring on global links, local
    /// broadcast.
    Hierarchical(Dragonfly),
}

impl AllReduceAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            AllReduceAlgo::Ring => "ring",
            AllReduceAlgo::Tree => "tree",
            AllReduceAlgo::Flat => "flat",
            AllReduceAlgo::Hierarchical(_) => "hierarchical",
        }
    }
}

/// α-β (latency-bandwidth) cost model for collectives.
///
/// Defaults approximate a Cray Aries dragonfly fabric: ~1.5 µs MPI
/// latency, ~10 GB/s effective per-node all-reduce bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency α in seconds (flat link class).
    pub alpha_s: f64,
    /// Effective bandwidth β in bytes/second (flat link class).
    pub beta_bytes_per_s: f64,
    /// Which collective schedule to cost.
    pub algo: AllReduceAlgo,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 10e9, algo: AllReduceAlgo::Ring }
    }
}

impl NetModel {
    /// An infinitely fast network (for algorithm-only studies).
    pub fn instant() -> Self {
        NetModel { alpha_s: 0.0, beta_bytes_per_s: f64::INFINITY, algo: AllReduceAlgo::Ring }
    }

    fn link(&self) -> Link {
        Link { alpha_s: self.alpha_s, beta_bytes_per_s: self.beta_bytes_per_s }
    }

    /// Resolve the configured schedule to its cost-model object.
    pub fn schedule(&self) -> Box<dyn CollectiveSchedule> {
        match self.algo {
            AllReduceAlgo::Ring => Box::new(schedule::Ring(self.link())),
            AllReduceAlgo::Tree => Box::new(schedule::Tree(self.link())),
            AllReduceAlgo::Flat => Box::new(schedule::FlatStar(self.link())),
            AllReduceAlgo::Hierarchical(topology) => {
                Box::new(schedule::Hierarchical { topology })
            }
        }
    }

    /// Per-phase time of one all-reduce of `n_elems` f32 across
    /// `n_ranks` (t_ARed(g, N) in Eq. 13/14, split local/global).
    pub fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        self.schedule().allreduce_phases(n_elems, n_ranks)
    }

    /// Total time for one all-reduce (the Eq. 13/14 t_AR).
    pub fn allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.allreduce_phases(n_elems, n_ranks).total()
    }

    /// Point-to-point time for `n_elems` f32 (used by the PS substrate:
    /// t_W2PS in Eq. 15), on the flat link class.
    pub fn ptp_time(&self, n_elems: usize) -> f64 {
        self.alpha_s + n_elems as f64 * 4.0 / self.beta_bytes_per_s
    }

    /// Topology-aware point-to-point time between two ranks: under a
    /// hierarchical schedule, ranks in the same dragonfly group talk
    /// over local links, others pay the global link; flat schedules
    /// fall back to [`NetModel::ptp_time`]. Prices a single dedicated
    /// flow — see [`NetModel::ptp_time_between_flows`] for the
    /// contended form.
    pub fn ptp_time_between(&self, from: usize, to: usize, n_elems: usize) -> f64 {
        self.ptp_time_between_flows(from, to, n_elems, 1)
    }

    /// [`NetModel::ptp_time_between`] with `flows` concurrent
    /// cross-group transfers sharing the tapered per-group global links
    /// ([`GlobalContention`]) — how the parameter-server engines price
    /// the many-to-few crossings into the PS's group. Same-group
    /// transfers and flat fabrics never contend.
    pub fn ptp_time_between_flows(
        &self,
        from: usize,
        to: usize,
        n_elems: usize,
        flows: usize,
    ) -> f64 {
        match self.algo {
            AllReduceAlgo::Hierarchical(d) => {
                let bytes = n_elems as f64 * 4.0;
                if d.group_of(from) == d.group_of(to) {
                    d.alpha_local_s + bytes / d.beta_local
                } else {
                    let link = d.contended_global_link(flows);
                    link.alpha_s + bytes / link.beta_bytes_per_s
                }
            }
            _ => self.ptp_time(n_elems),
        }
    }

    /// Barrier cost (log-tree of empty messages).
    pub fn barrier_time(&self, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            0.0
        } else {
            2.0 * (n_ranks as f64).log2().ceil() * self.alpha_s
        }
    }
}

// ---------------------------------------------------------------------------
// Rendezvous collectives
// ---------------------------------------------------------------------------

/// Which round-resolution backend a [`Group`] runs on.
///
/// Both backends produce **bit-identical** payloads, contributor sets,
/// and completion times — they differ only in how per-round state is
/// represented and how completion is detected:
///
/// * [`SimBackend::Dense`] materializes a capacity-wide slot vector per
///   round and decides completion by scanning the roster — the PR 7
///   behaviour, O(capacity) per post.
/// * [`SimBackend::Folded`] keeps a poster-only arena (sorted by rank)
///   and resolves completion from the group's **contributor-set
///   deltas**: the expected contributor count of round `seq` is the
///   prefix sum of admit/depart deltas up to `seq`, so a post or a
///   departure re-checks completion in O(log capacity) — the event-core
///   representation that scales the rendezvous substrate past the
///   all-materialized regime.
///
/// The seal path is shared: contributions are drained in ascending rank
/// order into the identical tiled reduction, so the dyadic float sum —
/// and therefore every downstream metric — is byte-equal across
/// backends (differential-tested by `prop_folded_backend_equals_dense`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimBackend {
    /// Capacity-wide materialized rounds (roster-scan completion).
    #[default]
    Dense,
    /// Poster-only arenas + contributor-delta completion counts.
    Folded,
}

impl SimBackend {
    pub fn name(&self) -> &'static str {
        match self {
            SimBackend::Dense => "dense",
            SimBackend::Folded => "folded",
        }
    }

    /// Parse a config spelling. Accepts `dense` and `folded`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Some(SimBackend::Dense),
            "folded" => Some(SimBackend::Folded),
            _ => None,
        }
    }
}

/// What a rendezvous round computes (and which schedule entry costs it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum RoundKind {
    /// Sum of equal-length contributions; everyone gets the sum.
    AllReduce,
    /// Sum of equal-length contributions; rank i keeps chunk i (the
    /// slicing happens at the caller — costed as a reduce-scatter).
    ReduceScatter,
    /// Rank-ordered concatenation of the contributions.
    AllGather,
    /// Root's contribution delivered to everyone (non-roots post `&[]`).
    Broadcast { root: usize },
}

/// One rank's membership interval, in round-sequence space. The
/// expected contributor set of round `seq` is exactly the ranks whose
/// interval contains `seq` — a pure function of the (deterministic)
/// admit/depart sequence numbers, never of thread timing.
#[derive(Debug, Clone, Copy)]
struct Member {
    /// First round sequence this rank participates in (`u64::MAX`
    /// until admitted).
    admit_seq: u64,
    /// First round sequence this rank will never post (set on leave).
    depart_seq: Option<u64>,
    /// Membership epoch the rank was (last) admitted under.
    joined_epoch: u64,
}

impl Member {
    fn expects(&self, seq: u64) -> bool {
        let not_departed = match self.depart_seq {
            Some(d) => seq < d,
            None => true,
        };
        self.admit_seq <= seq && not_departed
    }

    fn is_active(&self) -> bool {
        self.admit_seq != u64::MAX && self.depart_seq.is_none()
    }
}

/// The completed payload of a round, shared by all its consumers.
#[derive(Debug, Clone)]
struct RoundResult {
    payload: Arc<Vec<f32>>,
    /// Shared completion time: `max(post) + t_collective`.
    t_complete: f64,
    phases: PhaseTimes,
    /// Ranks that actually contributed (ascending). Shorter than the
    /// posting epoch's world when the round resolved over survivors.
    contributors: Arc<Vec<usize>>,
}

/// Per-round contribution storage — the backend split.
///
/// Whatever the representation, contributions drain in **ascending rank
/// order** through [`RoundParts::take_contributions`], so the reduction
/// downstream is bit-deterministic regardless of arrival order (float
/// addition is not associative) and identical across backends.
enum RoundParts {
    /// Capacity-wide slot per rank (dense backend).
    Dense(Vec<Option<Vec<f32>>>),
    /// Poster-only arena, kept sorted by rank (folded backend).
    Folded(Vec<(usize, Vec<f32>)>),
}

impl RoundParts {
    fn new(backend: SimBackend, capacity: usize) -> Self {
        match backend {
            SimBackend::Dense => RoundParts::Dense((0..capacity).map(|_| None).collect()),
            SimBackend::Folded => RoundParts::Folded(Vec::new()),
        }
    }

    /// Record `rank`'s contribution. Panics on a double post.
    fn insert(&mut self, rank: usize, data: Vec<f32>, seq: u64) {
        match self {
            RoundParts::Dense(slots) => {
                assert!(slots[rank].is_none(), "rank {rank} double-posted round {seq}");
                slots[rank] = Some(data);
            }
            RoundParts::Folded(arena) => match arena.binary_search_by_key(&rank, |(r, _)| *r) {
                Ok(_) => panic!("rank {rank} double-posted round {seq}"),
                Err(pos) => arena.insert(pos, (rank, data)),
            },
        }
    }

    fn has(&self, rank: usize) -> bool {
        match self {
            RoundParts::Dense(slots) => slots[rank].is_some(),
            RoundParts::Folded(arena) => {
                arena.binary_search_by_key(&rank, |(r, _)| *r).is_ok()
            }
        }
    }

    fn posted_count(&self) -> usize {
        match self {
            RoundParts::Dense(slots) => slots.iter().filter(|p| p.is_some()).count(),
            RoundParts::Folded(arena) => arena.len(),
        }
    }

    /// Drain every contribution, ascending by rank — the single seal
    /// entry point both backends share.
    fn take_contributions(&mut self) -> Vec<(usize, Vec<f32>)> {
        match self {
            RoundParts::Dense(slots) => slots
                .iter_mut()
                .enumerate()
                .filter_map(|(r, p)| p.take().map(|d| (r, d)))
                .collect(),
            RoundParts::Folded(arena) => std::mem::take(arena),
        }
    }
}

struct Round {
    /// Per-rank contributions, reduced in rank order on completion so
    /// the result is bit-deterministic regardless of thread arrival
    /// order — and bit-identical across schedules *and backends*, which
    /// only decide cost and representation respectively.
    parts: RoundParts,
    max_post_time: f64,
    kind: RoundKind,
    /// Schedule costing this round (first poster's choice; the
    /// deterministic controllers guarantee every rank picks the same).
    algo: AllReduceAlgo,
    /// Wire volume override in f32-equivalent elements — the size the
    /// cost model prices instead of the payload length. The gradient
    /// compression hook: a quantized payload still travels (and sums)
    /// as dense f32s, but the modelled round moves `bits/32` of the
    /// bytes. `None` prices the actual payload. First poster's choice,
    /// same determinism contract as `algo`.
    wire_elems: Option<usize>,
    result: Option<RoundResult>,
    consumed: usize,
}

/// Is every rank expected for `seq` posted into `round`?
///
/// Dense: scan the roster against the materialized slots. Folded:
/// compare the posted count against the contributor-set delta prefix
/// sum — only expected ranks ever post (debug-asserted at the post
/// site), so count equality is membership equality.
fn round_ready(
    backend: SimBackend,
    roster: &[Member],
    deltas: &BTreeMap<u64, i64>,
    round: &Round,
    seq: u64,
) -> bool {
    match backend {
        SimBackend::Dense => {
            roster.iter().enumerate().all(|(r, m)| !m.expects(seq) || round.parts.has(r))
        }
        SimBackend::Folded => {
            let expected: i64 = deltas.range(..=seq).map(|(_, d)| *d).sum();
            let posted = round.parts.posted_count() as i64;
            debug_assert!(
                posted <= expected,
                "round {seq}: {posted} posts exceed the {expected} expected contributors"
            );
            posted >= expected
        }
    }
}

impl Round {
    /// Reduce the parts per the round kind over the ranks that posted;
    /// returns (payload, phases, contributors). The cost model prices
    /// the collective at the contributor count — a round that resolved
    /// over survivors ran over survivors.
    fn finish(&mut self, net: &NetModel, seq: u64) -> (Vec<f32>, PhaseTimes, Vec<usize>) {
        let parts = self.parts.take_contributions();
        assert!(!parts.is_empty(), "round {seq} completed with no contributors");
        let contributors: Vec<usize> = parts.iter().map(|(r, _)| *r).collect();
        let n_ranks = contributors.len();
        let sched_net = NetModel { algo: self.algo, ..*net };
        let (payload, phases) = match self.kind {
            RoundKind::AllReduce | RoundKind::ReduceScatter => {
                let len = parts[0].1.len();
                let mut sum = vec![0.0f32; len];
                for (_, part) in &parts {
                    assert_eq!(
                        part.len(),
                        sum.len(),
                        "mismatched all-reduce lengths in round {seq}"
                    );
                }
                // Tile the reduction so each ~4 KB stripe of the sum
                // stays in cache across all contributors. Per element
                // the additions still land in ascending contributor
                // order, so the dyadic result is bit-identical to the
                // untiled loop — and to either backend's storage.
                const SEAL_TILE: usize = 1024;
                let mut start = 0;
                while start < len {
                    let end = (start + SEAL_TILE).min(len);
                    let dst = &mut sum[start..end];
                    for (_, part) in &parts {
                        for (a, x) in dst.iter_mut().zip(&part[start..end]) {
                            *a += x;
                        }
                    }
                    start = end;
                }
                let wire = self.wire_elems.unwrap_or(len);
                let phases = if self.kind == RoundKind::AllReduce {
                    sched_net.schedule().allreduce_phases(wire, n_ranks)
                } else {
                    sched_net.schedule().reduce_scatter_phases(wire, n_ranks)
                };
                (sum, phases)
            }
            RoundKind::AllGather => {
                let per = parts[0].1.len();
                let mut out = Vec::with_capacity(per * n_ranks);
                for (_, part) in &parts {
                    assert_eq!(part.len(), per, "mismatched all-gather lengths in round {seq}");
                    out.extend_from_slice(part);
                }
                let wire = self.wire_elems.unwrap_or(per);
                let phases = sched_net.schedule().allgather_phases(wire, n_ranks);
                (out, phases)
            }
            RoundKind::Broadcast { root } => {
                let payload = parts
                    .into_iter()
                    .find(|(r, _)| *r == root)
                    .map(|(_, d)| d)
                    .expect("root posted");
                let phases = sched_net.schedule().bcast_phases(payload.len(), n_ranks);
                (payload, phases)
            }
        };
        (payload, phases, contributors)
    }

    /// Finalize: compute and store the result, off the shared mutex's
    /// critical data (caller holds the lock).
    fn seal(&mut self, net: &NetModel, seq: u64) {
        let (payload, phases, contributors) = self.finish(net, seq);
        self.result = Some(RoundResult {
            payload: Arc::new(payload),
            t_complete: self.max_post_time + phases.total(),
            phases,
            contributors: Arc::new(contributors),
        });
    }
}

/// The canonical state a joiner bootstraps from, published by the
/// survivors of an epoch transition (first publisher wins; every
/// survivor computes bit-identical content).
#[derive(Debug, Clone)]
pub struct JoinBootstrap {
    /// Epoch this bootstrap belongs to.
    pub epoch: u64,
    /// The epoch-boundary averaged weights (bit-identical on every
    /// member of the new epoch).
    pub weights: Arc<Vec<f32>>,
    /// Virtual time the epoch began (the resync round's completion).
    pub t_start: f64,
    /// Cumulative healthy-rank step count at the boundary (the
    /// engines' termination currency — identical across ranks).
    pub sched_steps: u64,
    /// Completed-window index at the boundary.
    pub window: u64,
    /// How many scripted joins have fired up to and including this
    /// epoch (the joiner resumes the membership schedule here — it
    /// cannot reconstruct the cursor from the member list, since an
    /// earlier joiner may have already departed again).
    pub join_cursor: usize,
}

struct State {
    rounds: HashMap<u64, Round>,
    epoch: u64,
    roster: Vec<Member>,
    /// Contributor-set deltas in round-sequence space: `+k` at every
    /// admit sequence, `−1` at every depart sequence. The expected
    /// contributor count of round `seq` is the prefix sum through
    /// `seq` — the pure-function-of-seq membership contract, kept in
    /// O(events) instead of O(capacity). Maintained under both backends
    /// (it is cheap); the folded backend resolves round completion from
    /// it alone.
    deltas: BTreeMap<u64, i64>,
    /// The member list **pinned at the epoch's first
    /// [`Comm::advance_epoch`] application** — the list every member of
    /// the epoch must agree on. The live roster can already have lost a
    /// member to a racing post-transition `leave()` by the time a slow
    /// survivor (or a waking joiner) reads it; the pinned snapshot is
    /// taken before any member can act post-transition (each member's
    /// `advance_epoch` call happens-before its subsequent departure),
    /// so it is identical for everyone.
    epoch_members: Vec<usize>,
    bootstrap: Option<JoinBootstrap>,
    /// Set when the run finishes; unblocks joiners that never fired.
    closed: bool,
}

impl State {
    fn members(&self) -> Vec<usize> {
        self.roster
            .iter()
            .enumerate()
            .filter(|(_, m)| m.is_active())
            .map(|(r, _)| r)
            .collect()
    }
}

struct Shared {
    capacity: usize,
    net: NetModel,
    backend: SimBackend,
    state: Mutex<State>,
    cv: Condvar,
    /// Execution gate shared with the engine worker pool (see
    /// [`crate::exec`]): every blocking wait releases its runnable
    /// permit for the wait's duration, so parked ranks never occupy a
    /// `--threads` slot. Defaults to the unlimited pass-through, which
    /// keeps non-pooled callers (unit tests, raw [`Group`] users)
    /// overhead-free.
    gate: Mutex<Arc<Gate>>,
}

impl Shared {
    fn gate(&self) -> Arc<Gate> {
        self.gate.lock().unwrap().clone()
    }
}

/// A communicator group. Create once, then [`Group::comm`] hands each
/// initial worker thread its endpoint; scripted joiners block in
/// [`Group::await_admission`] until the survivors admit them.
pub struct Group {
    shared: Arc<Shared>,
}

impl Group {
    /// A fixed group of `n` ranks (the non-elastic default: everyone
    /// admitted at sequence 0, nobody leaves).
    pub fn new(n: usize, net: NetModel) -> Self {
        Self::elastic(n, n, net)
    }

    /// An elastic group: ranks `0..initial` are members from the start;
    /// ranks `initial..capacity` are reserved slots for scripted
    /// joiners (inactive until [`Comm::advance_epoch`] admits them).
    pub fn elastic(capacity: usize, initial: usize, net: NetModel) -> Self {
        Self::with_backend(capacity, initial, net, SimBackend::default())
    }

    /// [`Group::elastic`] on an explicit round-resolution backend —
    /// the knob `[sim] backend` in the experiment config plumbs here.
    /// Both backends are bit-identical (see [`SimBackend`]).
    pub fn with_backend(
        capacity: usize,
        initial: usize,
        net: NetModel,
        backend: SimBackend,
    ) -> Self {
        assert!(initial >= 1 && capacity >= initial);
        let roster = (0..capacity)
            .map(|r| Member {
                admit_seq: if r < initial { 0 } else { u64::MAX },
                depart_seq: None,
                joined_epoch: 0,
            })
            .collect();
        let mut deltas = BTreeMap::new();
        deltas.insert(0u64, initial as i64);
        Group {
            shared: Arc::new(Shared {
                capacity,
                net,
                backend,
                state: Mutex::new(State {
                    rounds: HashMap::new(),
                    epoch: 0,
                    roster,
                    deltas,
                    epoch_members: (0..initial).collect(),
                    bootstrap: None,
                    closed: false,
                }),
                cv: Condvar::new(),
                gate: Mutex::new(Gate::unlimited()),
            }),
        }
    }

    /// Which round-resolution backend this group runs on.
    pub fn backend(&self) -> SimBackend {
        self.shared.backend
    }

    /// Plug the engine pool's execution [`Gate`] into this group's
    /// blocking waits. Must be called before any collective traffic
    /// (the engines do it right after constructing the group); waits in
    /// flight at swap time would release the old gate and reacquire the
    /// new one.
    pub fn set_gate(&self, gate: Arc<Gate>) {
        *self.shared.gate.lock().unwrap() = gate;
    }

    /// Endpoint for an *initial* member. Each rank must be handed out
    /// exactly once; sequence numbers are tracked per-endpoint.
    pub fn comm(&self, rank: usize) -> Comm {
        {
            let st = self.shared.state.lock().unwrap();
            assert!(rank < self.shared.capacity, "rank {rank} out of capacity");
            assert!(st.roster[rank].admit_seq == 0, "rank {rank} is not an initial member");
        }
        Comm { rank, shared: self.shared.clone(), next_seq: 0 }
    }

    /// Block until `rank` is admitted by an epoch transition *and* the
    /// epoch's bootstrap is published, then return its endpoint (fast-
    /// forwarded to the epoch's first round) plus the bootstrap.
    /// Returns `None` if the run closes before the join fires.
    pub fn await_admission(&self, rank: usize) -> Option<(Comm, JoinBootstrap)> {
        assert!(rank < self.shared.capacity, "rank {rank} out of capacity");
        let mut st = self.shared.state.lock().unwrap();
        // A pre-admission joiner parks here for most of the run — give
        // its runnable permit back to the pool while it waits.
        let mut parked = false;
        let out = loop {
            let m = st.roster[rank];
            if m.admit_seq != u64::MAX {
                if let Some(boot) = st.bootstrap.clone() {
                    if boot.epoch == m.joined_epoch {
                        let comm =
                            Comm { rank, shared: self.shared.clone(), next_seq: m.admit_seq };
                        break Some((comm, boot));
                    }
                }
            }
            if st.closed {
                break None;
            }
            if !parked {
                self.shared.gate().release();
                parked = true;
            }
            st = self.shared.cv.wait(st).unwrap();
        };
        drop(st);
        if parked {
            self.shared.gate().acquire();
        }
        out
    }

    /// Current world size (active members).
    pub fn n_ranks(&self) -> usize {
        self.shared.state.lock().unwrap().members().len()
    }

    /// Sorted active member ranks.
    pub fn members(&self) -> Vec<usize> {
        self.shared.state.lock().unwrap().members()
    }

    /// Current membership epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.state.lock().unwrap().epoch
    }

    /// Mark the run finished: joiners whose scripted event never fired
    /// stop waiting. Idempotent.
    pub fn shutdown(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.closed = true;
        self.shared.cv.notify_all();
    }
}

/// Per-rank communicator endpoint (the `MPI_COMM_WORLD` handle).
pub struct Comm {
    rank: usize,
    shared: Arc<Shared>,
    next_seq: u64,
}

/// In-flight non-blocking collective (the `MPI_Request`).
/// Dropping without [`PendingReduce::wait`] leaks the round — like
/// losing an MPI request; debug builds assert against it.
#[must_use = "a posted collective must be completed with wait()"]
pub struct PendingReduce {
    seq: u64,
    rank: usize,
    shared: Arc<Shared>,
    /// Virtual time at which this rank posted the operation.
    pub post_time: f64,
    done: bool,
}

/// Everything a completed round hands back: payload, timing, phase
/// split, and — the elastic-membership signal — who contributed.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    pub data: Arc<Vec<f32>>,
    /// This rank's virtual time after the wait: `max(now, t_complete)`.
    pub time: f64,
    /// Shared completion time of the collective (identical on every
    /// rank — the deterministic anchor membership transitions key on).
    pub t_complete: f64,
    pub phases: PhaseTimes,
    /// Ranks that contributed, ascending. A shrink shows up here: the
    /// consumer re-weights the mean by `contributors.len()`.
    pub contributors: Arc<Vec<usize>>,
}

impl RoundOutcome {
    /// Exposed (non-overlapped) wait, given the virtual instant the
    /// rank entered the wait — the `blocked_s` the obs layer accounts
    /// per window. Zero when the round had already sealed.
    pub fn blocked_since(&self, wait_start: f64) -> f64 {
        (self.time - wait_start).max(0.0)
    }

    /// End-to-end collective latency t_AR, given the post instant —
    /// the denominator of the per-window overlap efficiency.
    pub fn latency_since(&self, post_time: f64) -> f64 {
        (self.time - post_time).max(0.0)
    }
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Current world size (active members of the current epoch).
    pub fn n_ranks(&self) -> usize {
        self.shared.state.lock().unwrap().members().len()
    }

    /// Sorted active member ranks of the current epoch.
    pub fn members(&self) -> Vec<usize> {
        self.shared.state.lock().unwrap().members()
    }

    /// Current membership epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.state.lock().unwrap().epoch
    }

    /// The group's network cost model (carrying the default schedule).
    pub fn net_model(&self) -> NetModel {
        self.shared.net
    }

    /// Post one rendezvous round of any kind. All ranks must pass the
    /// same (kind, algo) for a given sequence number — guaranteed by
    /// the control plane's determinism contract.
    pub(crate) fn post(
        &mut self,
        data: &[f32],
        now: f64,
        kind: RoundKind,
        algo: AllReduceAlgo,
    ) -> PendingReduce {
        self.post_wire(data, now, kind, algo, None)
    }

    /// [`Comm::post`] with an explicit wire-volume override for the
    /// cost model (the compression hook). All ranks must pass the same
    /// (kind, algo, wire_elems) for a given sequence number.
    pub(crate) fn post_wire(
        &mut self,
        data: &[f32],
        now: f64,
        kind: RoundKind,
        algo: AllReduceAlgo,
        wire_elems: Option<usize>,
    ) -> PendingReduce {
        let seq = self.next_seq;
        self.next_seq += 1;
        let capacity = self.shared.capacity;
        let backend = self.shared.backend;
        let mut guard = self.shared.state.lock().unwrap();
        let State { rounds, roster, deltas, .. } = &mut *guard;
        debug_assert!(
            roster[self.rank].expects(seq),
            "rank {} posting round {seq} outside its membership interval",
            self.rank
        );
        let round = rounds.entry(seq).or_insert_with(|| Round {
            parts: RoundParts::new(backend, capacity),
            max_post_time: f64::NEG_INFINITY,
            kind,
            algo,
            wire_elems,
            result: None,
            consumed: 0,
        });
        debug_assert!(
            round.kind == kind && round.algo == algo && round.wire_elems == wire_elems,
            "rank {} disagrees on round {seq} shape: {:?}/{:?}/{:?} vs {:?}/{:?}/{:?}",
            self.rank,
            round.kind,
            round.algo,
            round.wire_elems,
            kind,
            algo,
            wire_elems
        );
        round.parts.insert(self.rank, data.to_vec(), seq);
        round.max_post_time = round.max_post_time.max(now);
        if round.result.is_none() && round_ready(backend, roster, deltas, round, seq) {
            round.seal(&self.shared.net, seq);
            self.shared.cv.notify_all();
        }
        PendingReduce {
            seq,
            rank: self.rank,
            shared: self.shared.clone(),
            post_time: now,
            done: false,
        }
    }

    /// Deregister this rank from the group: it will never post a round
    /// at or beyond its current sequence number. Any in-flight round
    /// waiting only on this rank resolves immediately over the
    /// survivors (re-weighted at the consumer — see [`RoundOutcome`]).
    /// Idempotent.
    pub fn leave(&mut self) {
        let backend = self.shared.backend;
        let mut guard = self.shared.state.lock().unwrap();
        let State { rounds, roster, deltas, .. } = &mut *guard;
        if roster[self.rank].depart_seq.is_some() {
            return;
        }
        roster[self.rank].depart_seq = Some(self.next_seq);
        *deltas.entry(self.next_seq).or_insert(0) -= 1;
        for (&seq, round) in rounds.iter_mut() {
            if round.result.is_none() && round_ready(backend, roster, deltas, round, seq) {
                round.seal(&self.shared.net, seq);
            }
        }
        self.shared.cv.notify_all();
    }

    /// Advance the membership epoch to `to_epoch`, admitting `joiners`
    /// (reserved, never-admitted ranks) with their first round set to
    /// `next_seq + 1` — i.e. *after* the epoch's survivors-only resync
    /// round at `next_seq`. Idempotent per epoch: every survivor calls
    /// this with identical arguments; the first caller applies the
    /// admissions and **pins the epoch's member list**, which every
    /// caller (however late) gets back — a racing post-transition
    /// `leave()` must not hand different worlds to different members.
    pub fn advance_epoch(&mut self, to_epoch: u64, joiners: &[usize]) -> Vec<usize> {
        let mut st = self.shared.state.lock().unwrap();
        if st.epoch < to_epoch {
            st.epoch = to_epoch;
            st.bootstrap = None;
            let admit = self.next_seq + 1;
            for &j in joiners {
                let m = &mut st.roster[j];
                assert!(m.admit_seq == u64::MAX, "join rank {j} already admitted");
                m.admit_seq = admit;
                m.joined_epoch = to_epoch;
            }
            if !joiners.is_empty() {
                *st.deltas.entry(admit).or_insert(0) += joiners.len() as i64;
            }
            st.epoch_members = st.members();
            self.shared.cv.notify_all();
        }
        st.epoch_members.clone()
    }

    /// The member list pinned at the current epoch's transition (what
    /// [`Comm::advance_epoch`] returned to every member) — the view a
    /// waking joiner must adopt, immune to later departures.
    pub fn epoch_members(&self) -> Vec<usize> {
        self.shared.state.lock().unwrap().epoch_members.clone()
    }

    /// Publish the canonical bootstrap for `boot.epoch`'s joiners.
    /// First publisher wins; every survivor computes identical content,
    /// so the choice of winner is immaterial.
    pub fn publish_bootstrap(&self, boot: JoinBootstrap) {
        let mut st = self.shared.state.lock().unwrap();
        // epochs start at 1, so an absent bootstrap (epoch "0") always
        // yields to the incoming one
        let newest = st.bootstrap.as_ref().map(|b| b.epoch).unwrap_or(0);
        if newest < boot.epoch {
            st.bootstrap = Some(boot);
            self.shared.cv.notify_all();
        }
    }

    /// Mark the run finished (see [`Group::shutdown`]). Idempotent.
    pub fn shutdown(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.closed = true;
        self.shared.cv.notify_all();
    }

    /// Non-blocking all-reduce (sum) — `MPI_Iallreduce`, on the group's
    /// default schedule.
    ///
    /// `now` is this rank's virtual time at the post. The operation's
    /// completion time is `max_i(post_i) + t_AR` per the schedule's cost
    /// model: the collective cannot start before its last participant
    /// arrives, and then takes `t_AR` — exactly the composition Eq. 14
    /// assumes.
    pub fn iallreduce(&mut self, data: &[f32], now: f64) -> PendingReduce {
        let algo = self.shared.net.algo;
        self.post(data, now, RoundKind::AllReduce, algo)
    }

    /// Non-blocking all-reduce on an explicit schedule — the control
    /// plane's per-window schedule override. Every rank must pass the
    /// same `algo` for the same round (deterministic controllers).
    pub fn iallreduce_sched(
        &mut self,
        data: &[f32],
        now: f64,
        algo: AllReduceAlgo,
    ) -> PendingReduce {
        self.post(data, now, RoundKind::AllReduce, algo)
    }

    /// Non-blocking all-reduce whose cost model prices `wire_elems`
    /// f32-equivalents instead of the payload length — a quantized
    /// payload still travels (and sums) as dense f32s, but the modelled
    /// round moves only the compressed bytes. Every rank must pass the
    /// same (algo, wire_elems) for the same round.
    pub fn iallreduce_wire(
        &mut self,
        data: &[f32],
        now: f64,
        algo: AllReduceAlgo,
        wire_elems: usize,
    ) -> PendingReduce {
        self.post_wire(data, now, RoundKind::AllReduce, algo, Some(wire_elems))
    }

    /// Non-blocking all-gather on an explicit schedule: the sparse
    /// round the top-k compressed engines use. Unlike the fixed-world
    /// [`Comm::allgather`] convenience wrapper, this is membership-
    /// epoch aware — the concatenation covers exactly the round's
    /// contributors (in ascending rank order), which the caller reads
    /// from [`RoundOutcome::contributors`].
    pub fn iallgather_sched(
        &mut self,
        data: &[f32],
        now: f64,
        algo: AllReduceAlgo,
    ) -> PendingReduce {
        self.post(data, now, RoundKind::AllGather, algo)
    }

    /// Blocking all-reduce — `MPI_Allreduce`. Returns (sum, completion
    /// virtual time for this rank).
    pub fn allreduce(&mut self, data: &[f32], now: f64) -> (Arc<Vec<f32>>, f64) {
        self.iallreduce(data, now).wait(now)
    }

    /// Blocking all-reduce on an explicit schedule; also returns the
    /// per-phase time split.
    pub fn allreduce_sched(
        &mut self,
        data: &[f32],
        now: f64,
        algo: AllReduceAlgo,
    ) -> (Arc<Vec<f32>>, f64, PhaseTimes) {
        self.iallreduce_sched(data, now, algo).wait_timed(now)
    }

    /// Barrier: all ranks must arrive; returns each rank's exit time
    /// `max_i(arrive_i) + t_barrier`.
    pub fn barrier(&mut self, now: f64) -> f64 {
        let world = self.n_ranks();
        let (_, t) = self.allreduce(&[], now);
        // allreduce of an empty payload costs α-terms only under Ring —
        // use the explicit barrier cost instead of the degenerate model.
        let mut t = t;
        if world > 1 {
            t += self.shared.net.barrier_time(world) - self.shared.net.allreduce_time(0, world);
        }
        t
    }
}

impl PendingReduce {
    /// Complete the operation — `MPI_Wait` — returning the full
    /// [`RoundOutcome`] (payload, exit time, shared completion time,
    /// phase split, contributor set).
    ///
    /// `now` is the rank's virtual time when it *calls* wait (i.e. after
    /// the overlapped computation). The returned time is
    /// `max(now, collective completion)` — the worker blocks only if
    /// the network is still busy, which is the whole point of the
    /// overlap (Eq. 14).
    pub fn wait_outcome(mut self, now: f64) -> RoundOutcome {
        let mut st = self.shared.state.lock().unwrap();
        // Fast path: an already-sealed round costs no gate traffic.
        // Slow path: hand the runnable permit back for the wait's
        // duration (gate release/notify never blocks, so doing it under
        // the state lock is safe) and reacquire it lock-free after.
        let mut parked = false;
        let out = loop {
            if let Some(round) = st.rounds.get_mut(&self.seq) {
                if let Some(res) = round.result.clone() {
                    round.consumed += 1;
                    if round.consumed >= res.contributors.len() {
                        st.rounds.remove(&self.seq);
                    }
                    self.done = true;
                    break RoundOutcome {
                        data: res.payload,
                        time: now.max(res.t_complete),
                        t_complete: res.t_complete,
                        phases: res.phases,
                        contributors: res.contributors,
                    };
                }
            }
            if !parked {
                self.shared.gate().release();
                parked = true;
            }
            st = self.shared.cv.wait(st).unwrap();
        };
        drop(st);
        if parked {
            self.shared.gate().acquire();
        }
        out
    }

    /// Complete the operation — `MPI_Wait` — returning the payload,
    /// this rank's virtual time after the wait, and the collective's
    /// per-phase time split.
    pub fn wait_timed(self, now: f64) -> (Arc<Vec<f32>>, f64, PhaseTimes) {
        let out = self.wait_outcome(now);
        (out.data, out.time, out.phases)
    }

    /// Complete the operation — `MPI_Wait` (payload + exit time only).
    pub fn wait(self, now: f64) -> (Arc<Vec<f32>>, f64) {
        let (sum, t, _) = self.wait_timed(now);
        (sum, t)
    }

    /// Non-destructive completion test — `MPI_Test` (no time advance).
    pub fn is_complete(&self) -> bool {
        let st = self.shared.state.lock().unwrap();
        st.rounds.get(&self.seq).map(|r| r.result.is_some()).unwrap_or(true)
    }
}

impl Drop for PendingReduce {
    fn drop(&mut self) {
        debug_assert!(
            self.done || std::thread::panicking(),
            "PendingReduce dropped without wait() (rank {}, seq {})",
            self.rank,
            self.seq
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_ranks<F, R>(n: usize, net: NetModel, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let group = Group::new(n, net);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let comm = group.comm(r);
                let f = f.clone();
                thread::spawn(move || f(comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        let results = spawn_ranks(4, NetModel::instant(), |mut c| {
            let mine = vec![c.rank() as f32, 1.0];
            let (sum, _) = c.allreduce(&mine, 0.0);
            sum.as_ref().clone()
        });
        for r in results {
            assert_eq!(r, vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        }
    }

    #[test]
    fn rounds_are_matched_by_sequence() {
        // Each rank runs several rounds; sums must match per-round even
        // though ranks post at different times/orders.
        let results = spawn_ranks(3, NetModel::instant(), |mut c| {
            let mut sums = Vec::new();
            for round in 0..5 {
                let mine = vec![(round * 10 + c.rank()) as f32];
                let (sum, _) = c.allreduce(&mine, round as f64);
                sums.push(sum[0]);
            }
            sums
        });
        for r in results {
            assert_eq!(r, vec![3.0, 33.0, 63.0, 93.0, 123.0]); // Σ(10r+i)
        }
    }

    #[test]
    fn completion_time_is_max_post_plus_tar() {
        // rank i posts at time i; completion must be max_post + t_AR for
        // every rank, and a rank waiting later perceives max(now, that).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e6, algo: AllReduceAlgo::Ring };
        // 1000 f32 = 4000 bytes; ring with N=4: 2*3*(4000/4)/4e6 = 1.5e-3
        let t_ar = net.allreduce_time(1000, 4);
        let results = spawn_ranks(4, net, move |mut c| {
            let post = c.rank() as f64;
            let h = c.iallreduce(&vec![1.0; 1000], post);
            let (_, t_done) = h.wait(post); // waits immediately
            t_done
        });
        let expect = 3.0 + t_ar;
        for t in results {
            assert!((t - expect).abs() < 1e-12, "t={t}, expect={expect}");
        }
    }

    #[test]
    fn overlap_hides_communication_eq14() {
        // Worker computes for t_c after posting; if t_c > t_AR the wait
        // must be free: exit time == post + t_c (Eq. 14's max).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e9, algo: AllReduceAlgo::Ring };
        let t_ar = net.allreduce_time(100_000, 2);
        assert!(t_ar > 0.0);
        let t_c = t_ar * 10.0;
        let results = spawn_ranks(2, net, move |mut c| {
            let h = c.iallreduce(&vec![1.0; 100_000], 0.0);
            let after_compute = t_c; // simulated overlapped compute
            let (_, t_done) = h.wait(after_compute);
            t_done
        });
        for t in results {
            assert!((t - t_c).abs() < 1e-15, "communication not hidden: {t} vs {t_c}");
        }
    }

    #[test]
    fn mpi_test_semantics() {
        let group = Group::new(2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let h0 = c0.iallreduce(&[1.0], 0.0);
        assert!(!h0.is_complete(), "only one rank posted");
        let h1 = c1.iallreduce(&[2.0], 0.0);
        assert!(h0.is_complete());
        let (s, _) = h0.wait(0.0);
        assert_eq!(s[0], 3.0);
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn staleness_two_outstanding_rounds() {
        // Two rounds in flight simultaneously (max-staleness 2, §V):
        // posts for round 1 happen before round 0 completes on rank 1.
        let group = Group::new(2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let a0 = c0.iallreduce(&[1.0], 0.0);
        let a1 = c0.iallreduce(&[10.0], 0.0);
        let b0 = c1.iallreduce(&[2.0], 0.0);
        let b1 = c1.iallreduce(&[20.0], 0.0);
        assert_eq!(a0.wait(0.0).0[0], 3.0);
        assert_eq!(b0.wait(0.0).0[0], 3.0);
        assert_eq!(a1.wait(0.0).0[0], 30.0);
        assert_eq!(b1.wait(0.0).0[0], 30.0);
    }

    #[test]
    fn net_model_formulas() {
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        // ring, N=8, 1M f32 (4MB): 2*7*(1e-6 + 4e6/8/1e9) = 14e-6 + 7e-3
        let t = net.allreduce_time(1_000_000, 8);
        assert!((t - (14e-6 + 7.0e-3)).abs() < 1e-9);
        // single rank: free
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        // flat is slower than ring for large payloads
        let flat = NetModel { algo: AllReduceAlgo::Flat, ..net };
        assert!(flat.allreduce_time(1_000_000, 8) > t);
        // tree beats ring on latency for tiny payloads at large N
        let tree = NetModel { algo: AllReduceAlgo::Tree, ..net };
        assert!(tree.allreduce_time(1, 64) < net.allreduce_time(1, 64));
    }

    #[test]
    fn allreduce_bandwidth_term_scales_with_size() {
        let net = NetModel::default();
        let t1 = net.allreduce_time(1_000_000, 16);
        let t2 = net.allreduce_time(2_000_000, 16);
        assert!(t2 > t1 * 1.9 && t2 < t1 * 2.1);
    }

    #[test]
    fn hierarchical_rounds_cost_hierarchical_time_and_sum_identically() {
        // Same inputs through a Ring group and a Hierarchical group:
        // sums bit-identical (schedules never touch the arithmetic),
        // completion times from the respective schedules.
        let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Dragonfly::default() };
        let flat = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        let hier = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..flat };
        let run = |net: NetModel| {
            spawn_ranks(4, net, |mut c| {
                let mine: Vec<f32> =
                    (0..100).map(|i| (i as f32 + 1.0) * 0.37 + c.rank() as f32).collect();
                let (sum, t) = c.allreduce(&mine, 0.0);
                (sum.as_ref().clone(), t)
            })
        };
        let ring_out = run(flat);
        let hier_out = run(hier);
        for ((rs, rt), (hs, ht)) in ring_out.iter().zip(&hier_out) {
            assert_eq!(rs, hs, "schedules changed the sum");
            assert!((rt - flat.allreduce_time(100, 4)).abs() < 1e-15);
            assert!((ht - hier.allreduce_time(100, 4)).abs() < 1e-15);
        }
        assert_ne!(ring_out[0].1, hier_out[0].1, "schedules should cost differently");
    }

    #[test]
    fn per_round_schedule_override() {
        // A group defaulting to Ring can run one round hierarchically;
        // the phase split must come back through wait_timed.
        let d = Dragonfly::default();
        let results = spawn_ranks(4, NetModel::default(), move |mut c| {
            let h = c.iallreduce_sched(&[1.0; 64], 0.0, AllReduceAlgo::Hierarchical(d));
            let (sum, t, phases) = h.wait_timed(0.0);
            (sum[0], t, phases)
        });
        let expect = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let want = expect.allreduce_phases(64, 4);
        for (s, t, phases) in results {
            assert_eq!(s, 4.0);
            assert_eq!(phases, want);
            assert!((t - want.total()).abs() < 1e-15);
        }
    }

    #[test]
    fn wire_priced_round_sums_dense_but_costs_compressed() {
        // A compressed round: the payload (and its sum) is dense, the
        // cost model prices the wire volume.
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e6, algo: AllReduceAlgo::Ring };
        let results = spawn_ranks(4, net, |mut c| {
            let h = c.iallreduce_wire(&vec![1.0f32; 1000], 0.0, AllReduceAlgo::Ring, 250);
            h.wait(0.0)
        });
        let expect_t = net.allreduce_time(250, 4);
        assert!(expect_t < net.allreduce_time(1000, 4));
        for (sum, t) in results {
            assert_eq!(sum[0], 4.0, "wire pricing must not touch the arithmetic");
            assert!((t - expect_t).abs() < 1e-15, "t={t} vs wire-priced {expect_t}");
        }
    }

    #[test]
    fn sparse_gather_round_concatenates_and_costs_per_rank_payload() {
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e6, algo: AllReduceAlgo::Ring };
        let results = spawn_ranks(3, net, |mut c| {
            let seg = [c.rank() as f32; 4];
            let out = c.iallgather_sched(&seg, 0.0, AllReduceAlgo::Ring).wait_outcome(0.0);
            (out.data.as_ref().clone(), out.time)
        });
        let expect_t = net.allgather_time(4, 3);
        for (data, t) in results {
            assert_eq!(data.len(), 12);
            assert_eq!(&data[..4], &[0.0; 4]);
            assert_eq!(&data[8..], &[2.0; 4]);
            assert!((t - expect_t).abs() < 1e-15);
        }
    }

    #[test]
    fn sparse_gather_resolves_over_survivors() {
        // A departure mid-round: the gathered payload covers exactly
        // the survivors, in rank order — the membership-aware sparse
        // path the compressed engines rely on.
        let group = Group::new(3, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let mut c2 = group.comm(2);
        c2.leave();
        let h0 = c0.iallgather_sched(&[1.0, 2.0], 0.0, AllReduceAlgo::Ring);
        let h1 = c1.iallgather_sched(&[3.0, 4.0], 0.0, AllReduceAlgo::Ring);
        let out = h0.wait_outcome(0.0);
        assert_eq!(out.contributors.as_ref(), &vec![0, 1]);
        assert_eq!(out.data.as_ref(), &vec![1.0, 2.0, 3.0, 4.0]);
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn ptp_time_between_uses_topology() {
        let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Dragonfly::default() };
        let net = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let local = net.ptp_time_between(0, 1, 1000); // same group
        let global = net.ptp_time_between(0, 2, 1000); // across groups
        assert!(global > local, "{global} vs {local}");
        // flat schedules ignore rank placement
        let flat = NetModel::default();
        assert_eq!(flat.ptp_time_between(0, 3, 1000), flat.ptp_time(1000));
    }

    #[test]
    fn contended_ptp_slows_cross_group_transfers_only() {
        let d = Dragonfly { groups: 2, nodes_per_group: 2, global_taper: 1, ..Dragonfly::default() };
        let net = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        // one flow: dedicated, identical to the flows-free spelling
        assert_eq!(net.ptp_time_between_flows(0, 2, 1000, 1), net.ptp_time_between(0, 2, 1000));
        // three concurrent crossings over one optic: bandwidth term ×3
        let one = net.ptp_time_between(0, 2, 1000);
        let three = net.ptp_time_between_flows(0, 2, 1000, 3);
        let bw = 1000.0 * 4.0 / d.beta_global;
        assert!((three - one - 2.0 * bw).abs() < 1e-15, "{three} vs {one} + 2×{bw}");
        // same-group transfers never contend
        assert_eq!(net.ptp_time_between_flows(0, 1, 1000, 64), net.ptp_time_between(0, 1, 1000));
        // flat fabrics ignore the flows argument entirely
        let flat = NetModel::default();
        assert_eq!(flat.ptp_time_between_flows(0, 3, 1000, 64), flat.ptp_time(1000));
    }

    // --- membership epochs ---

    #[test]
    fn leave_resolves_in_flight_round_over_survivors() {
        // 3 ranks post round 0; rank 2 posts round 0 but then leaves
        // before round 1. Round 1 must resolve over ranks {0, 1} with
        // the survivor-set sum and contributor list.
        let group = Group::new(3, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let mut c2 = group.comm(2);
        let h0a = c0.iallreduce(&[1.0], 0.0);
        let h1a = c1.iallreduce(&[2.0], 0.0);
        let h2a = c2.iallreduce(&[4.0], 0.0);
        assert_eq!(h2a.wait(0.0).0[0], 7.0);
        // survivors post round 1 first — it must stay open
        let h0b = c0.iallreduce(&[10.0], 0.0);
        assert!(!h0b.is_complete());
        let h1b = c1.iallreduce(&[20.0], 0.0);
        assert!(!h1b.is_complete(), "round must wait for rank 2 or its departure");
        c2.leave();
        assert!(h0b.is_complete(), "departure must resolve the in-flight round");
        let out = h0b.wait_outcome(0.0);
        assert_eq!(out.data[0], 30.0, "survivor-set sum");
        assert_eq!(out.contributors.as_ref(), &vec![0, 1]);
        let (s1, _) = h1b.wait(0.0);
        assert_eq!(s1[0], 30.0);
        assert_eq!(group.members(), vec![0, 1]);
        // drain rank 0/1's round-0 handles
        h0a.wait(0.0).0.as_ref();
        h1a.wait(0.0).0.as_ref();
    }

    #[test]
    fn short_round_costs_the_survivor_count() {
        // A round resolved over 2 of 3 ranks is priced as a 2-rank
        // collective (it ran over 2 ranks).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e6, algo: AllReduceAlgo::Ring };
        let group = Group::new(3, net);
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let mut c2 = group.comm(2);
        c2.leave();
        let h0 = c0.iallreduce(&vec![1.0; 1000], 1.0);
        let h1 = c1.iallreduce(&vec![1.0; 1000], 2.0);
        let out = h0.wait_outcome(0.0);
        assert_eq!(out.contributors.len(), 2);
        assert!((out.t_complete - (2.0 + net.allreduce_time(1000, 2))).abs() < 1e-12);
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn advance_epoch_admits_joiner_after_resync_round() {
        let group = Group::elastic(3, 2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        assert_eq!(group.members(), vec![0, 1]);

        let joiner = thread::spawn({
            let shared = Group { shared: group.shared.clone() };
            move || shared.await_admission(2)
        });

        // both survivors run the identical transition; the second call
        // is a no-op
        let members = c0.advance_epoch(1, &[2]);
        assert_eq!(members, vec![0, 1, 2]);
        assert_eq!(c1.advance_epoch(1, &[2]), vec![0, 1, 2]);
        assert_eq!(group.epoch(), 1);

        // the resync round (seq 0) is survivors-only
        let h0 = c0.iallreduce(&[1.0], 0.0);
        let h1 = c1.iallreduce(&[3.0], 0.0);
        let out = h0.wait_outcome(0.0);
        assert_eq!(out.contributors.as_ref(), &vec![0, 1]);
        assert_eq!(out.data[0], 4.0);
        h1.wait(0.0).0.as_ref();

        c0.publish_bootstrap(JoinBootstrap {
            epoch: 1,
            weights: Arc::new(vec![2.0]),
            t_start: 5.0,
            sched_steps: 7,
            window: 3,
            join_cursor: 1,
        });
        let (mut c2, boot) = joiner.join().unwrap().expect("joiner admitted");
        assert_eq!(boot.weights[0], 2.0);
        assert_eq!(boot.sched_steps, 7);

        // the first post-admission round expects all three ranks
        let h0 = c0.iallreduce(&[1.0], 0.0);
        let h1 = c1.iallreduce(&[1.0], 0.0);
        assert!(!h0.is_complete());
        let h2 = c2.iallreduce(&[1.0], 0.0);
        let out = h2.wait_outcome(0.0);
        assert_eq!(out.data[0], 3.0);
        assert_eq!(out.contributors.len(), 3);
        h0.wait(0.0).0.as_ref();
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn late_advance_epoch_callers_see_the_pinned_member_list() {
        // A member that departs right after the transition must not
        // change the world a slower survivor (or a waking joiner) gets:
        // the epoch's member list is pinned at first application.
        let group = Group::elastic(3, 3, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let mut c2 = group.comm(2);
        assert_eq!(c0.advance_epoch(1, &[]), vec![0, 1, 2]);
        c2.leave(); // races ahead of the slow survivor's call
        assert_eq!(c1.advance_epoch(1, &[]), vec![0, 1, 2], "late caller got the live roster");
        assert_eq!(c1.epoch_members(), vec![0, 1, 2]);
        assert_eq!(group.members(), vec![0, 1], "the live view does shrink");
    }

    #[test]
    fn shutdown_unblocks_never_admitted_joiner() {
        let group = Group::elastic(2, 1, NetModel::instant());
        let joiner = thread::spawn({
            let shared = Group { shared: group.shared.clone() };
            move || shared.await_admission(1)
        });
        group.shutdown();
        assert!(joiner.join().unwrap().is_none());
    }

    #[test]
    fn non_elastic_groups_report_full_membership() {
        let group = Group::new(4, NetModel::instant());
        assert_eq!(group.n_ranks(), 4);
        assert_eq!(group.members(), vec![0, 1, 2, 3]);
        assert_eq!(group.epoch(), 0);
    }

    // --- folded backend parity ---

    fn spawn_ranks_backend<F, R>(n: usize, net: NetModel, backend: SimBackend, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let group = Group::with_backend(n, n, net, backend);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let comm = group.comm(r);
                let f = f.clone();
                thread::spawn(move || f(comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn folded_backend_is_bit_identical_to_dense() {
        // Same multi-round workload on both backends: payloads and
        // completion times must be byte-equal — the seal path drains
        // contributions in the same ascending order either way.
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        let run = |backend| {
            spawn_ranks_backend(4, net, backend, |mut c| {
                let mut out = Vec::new();
                for round in 0..4 {
                    let mine: Vec<f32> = (0..300)
                        .map(|i| (i as f32 + 0.13) * 0.37 + (c.rank() * 31 + round) as f32)
                        .collect();
                    let (sum, t) = c.allreduce(&mine, round as f64 * 0.5);
                    out.push((sum.as_ref().clone(), t));
                }
                out
            })
        };
        let dense = run(SimBackend::Dense);
        let folded = run(SimBackend::Folded);
        assert_eq!(dense.len(), folded.len());
        for (d, f) in dense.iter().zip(&folded) {
            for ((ds, dt), (fs, ft)) in d.iter().zip(f) {
                assert_eq!(ds, fs, "payloads diverged across backends");
                assert_eq!(dt.to_bits(), ft.to_bits(), "times diverged across backends");
            }
        }
    }

    #[test]
    fn folded_leave_resolves_in_flight_round_over_survivors() {
        // The delta prefix sum must shrink the expectation of rounds at
        // or beyond the departure sequence — and only those.
        let group = Group::with_backend(3, 3, NetModel::instant(), SimBackend::Folded);
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let mut c2 = group.comm(2);
        let h0a = c0.iallreduce(&[1.0], 0.0);
        let h1a = c1.iallreduce(&[2.0], 0.0);
        let h2a = c2.iallreduce(&[4.0], 0.0);
        assert_eq!(h2a.wait(0.0).0[0], 7.0);
        let h0b = c0.iallreduce(&[10.0], 0.0);
        assert!(!h0b.is_complete());
        let h1b = c1.iallreduce(&[20.0], 0.0);
        assert!(!h1b.is_complete(), "round must wait for rank 2 or its departure");
        c2.leave();
        assert!(h0b.is_complete(), "departure must resolve the in-flight round");
        let out = h0b.wait_outcome(0.0);
        assert_eq!(out.data[0], 30.0, "survivor-set sum");
        assert_eq!(out.contributors.as_ref(), &vec![0, 1]);
        h1b.wait(0.0).0.as_ref();
        h0a.wait(0.0).0.as_ref();
        h1a.wait(0.0).0.as_ref();
    }

    #[test]
    fn folded_advance_epoch_admits_joiner_after_resync_round() {
        // The admit delta lands at next_seq + 1: the resync round stays
        // survivors-only, the next expects the joiner too.
        let group = Group::with_backend(3, 2, NetModel::instant(), SimBackend::Folded);
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let joiner = thread::spawn({
            let shared = Group { shared: group.shared.clone() };
            move || shared.await_admission(2)
        });
        assert_eq!(c0.advance_epoch(1, &[2]), vec![0, 1, 2]);
        assert_eq!(c1.advance_epoch(1, &[2]), vec![0, 1, 2]);
        let h0 = c0.iallreduce(&[1.0], 0.0);
        let h1 = c1.iallreduce(&[3.0], 0.0);
        let out = h0.wait_outcome(0.0);
        assert_eq!(out.contributors.as_ref(), &vec![0, 1], "resync is survivors-only");
        assert_eq!(out.data[0], 4.0);
        h1.wait(0.0).0.as_ref();
        c0.publish_bootstrap(JoinBootstrap {
            epoch: 1,
            weights: Arc::new(vec![2.0]),
            t_start: 5.0,
            sched_steps: 7,
            window: 3,
            join_cursor: 1,
        });
        let (mut c2, _) = joiner.join().unwrap().expect("joiner admitted");
        let h0 = c0.iallreduce(&[1.0], 0.0);
        let h1 = c1.iallreduce(&[1.0], 0.0);
        assert!(!h0.is_complete(), "post-admission round must expect the joiner");
        let h2 = c2.iallreduce(&[1.0], 0.0);
        let out = h2.wait_outcome(0.0);
        assert_eq!(out.data[0], 3.0);
        assert_eq!(out.contributors.len(), 3);
        h0.wait(0.0).0.as_ref();
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn folded_sparse_gather_concatenates_in_rank_order() {
        // The arena arrives sorted even when ranks post out of order.
        let group = Group::with_backend(3, 3, NetModel::instant(), SimBackend::Folded);
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let mut c2 = group.comm(2);
        let h2 = c2.iallgather_sched(&[2.0, 2.0], 0.0, AllReduceAlgo::Ring);
        let h0 = c0.iallgather_sched(&[0.0, 0.0], 0.0, AllReduceAlgo::Ring);
        let h1 = c1.iallgather_sched(&[1.0, 1.0], 0.0, AllReduceAlgo::Ring);
        let out = h2.wait_outcome(0.0);
        assert_eq!(out.data.as_ref(), &vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        h0.wait(0.0).0.as_ref();
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn backend_parse_and_name_round_trip() {
        assert_eq!(SimBackend::parse("dense"), Some(SimBackend::Dense));
        assert_eq!(SimBackend::parse("Folded"), Some(SimBackend::Folded));
        assert_eq!(SimBackend::parse("sparse"), None);
        assert_eq!(SimBackend::default().name(), "dense");
        assert_eq!(SimBackend::Folded.name(), "folded");
        assert_eq!(Group::new(2, NetModel::instant()).backend(), SimBackend::Dense);
    }
}
