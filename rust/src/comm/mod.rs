//! Decentralized communication substrate — the simulated-MPI layer.
//!
//! The paper's algorithm needs exactly two things from MPI:
//! `MPI_Iallreduce` (non-blocking sum across all workers) and
//! `MPI_Wait`. This module provides them for N in-process workers with
//! **collective semantics identical to MPI** (every rank contributes
//! once per round, every rank receives the full sum, rounds complete in
//! sequence order) and **timing from an explicit α-β network model**
//! parameterised to Aries-like numbers (DESIGN.md §3 substitution
//! table).
//!
//! Two layers:
//! * [`Group`] / [`Comm`] — the rendezvous-based collectives the
//!   training engines use. Data movement is exact (f32 sum); completion
//!   *time* comes from [`NetModel`], carried on the worker's virtual
//!   clock ([`crate::simtime`]). Non-blocking handles capture the post
//!   time, so overlap accounting reproduces Eq. 14's
//!   `max(t_C, t_AR)` exactly.
//! * [`ring`] — a wire-level ring all-reduce (reduce-scatter +
//!   all-gather over per-edge channels) used by the comm benches and as
//!   a cross-check that the rendezvous sum matches a real decentralized
//!   schedule.

pub mod collectives;
pub mod ring;
pub mod topology;

pub use topology::Dragonfly;

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// All-reduce algorithm whose cost model [`NetModel`] applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// Ring: 2(N−1) steps of n/N elements — bandwidth-optimal, the
    /// algorithm Cray-mpich uses for large payloads.
    Ring,
    /// Binary tree reduce + broadcast: 2·⌈log2 N⌉ full-payload hops.
    Tree,
    /// Flat gather+scatter through rank 0 (the degenerate PS-like
    /// pattern; included for the centralised-vs-decentralised ablation).
    Flat,
}

/// α-β (latency-bandwidth) cost model for collectives.
///
/// Defaults approximate a Cray Aries dragonfly fabric: ~1.5 µs MPI
/// latency, ~10 GB/s effective per-node all-reduce bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency α in seconds.
    pub alpha_s: f64,
    /// Effective bandwidth β in bytes/second.
    pub beta_bytes_per_s: f64,
    /// Which collective schedule to cost.
    pub algo: AllReduceAlgo,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 10e9, algo: AllReduceAlgo::Ring }
    }
}

impl NetModel {
    /// An infinitely fast network (for algorithm-only studies).
    pub fn instant() -> Self {
        NetModel { alpha_s: 0.0, beta_bytes_per_s: f64::INFINITY, algo: AllReduceAlgo::Ring }
    }

    /// Time for one all-reduce of `n_elems` f32 across `n_ranks`
    /// (t_ARed(g, N) in Eq. 13/14).
    pub fn allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            return 0.0;
        }
        let bytes = n_elems as f64 * 4.0;
        let n = n_ranks as f64;
        match self.algo {
            AllReduceAlgo::Ring => {
                // 2(N−1) steps, each sending bytes/N.
                2.0 * (n - 1.0) * (self.alpha_s + bytes / n / self.beta_bytes_per_s)
            }
            AllReduceAlgo::Tree => {
                let hops = 2.0 * (n_ranks as f64).log2().ceil();
                hops * (self.alpha_s + bytes / self.beta_bytes_per_s)
            }
            AllReduceAlgo::Flat => {
                // root receives N−1 payloads then sends N−1 payloads,
                // fully serialized: the many-to-few bottleneck.
                2.0 * (n - 1.0) * (self.alpha_s + bytes / self.beta_bytes_per_s)
            }
        }
    }

    /// Point-to-point time for `n_elems` f32 (used by the PS substrate:
    /// t_W2PS in Eq. 15).
    pub fn ptp_time(&self, n_elems: usize) -> f64 {
        self.alpha_s + n_elems as f64 * 4.0 / self.beta_bytes_per_s
    }

    /// Barrier cost (log-tree of empty messages).
    pub fn barrier_time(&self, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            0.0
        } else {
            2.0 * (n_ranks as f64).log2().ceil() * self.alpha_s
        }
    }
}

// ---------------------------------------------------------------------------
// Rendezvous collectives
// ---------------------------------------------------------------------------

struct Round {
    /// Per-rank contributions, summed in rank order on completion so
    /// the result is bit-deterministic regardless of thread arrival
    /// order (float addition is not associative).
    parts: Vec<Option<Vec<f32>>>,
    contributions: usize,
    max_post_time: f64,
    /// Sum + sim completion time, set when the last rank contributes.
    result: Option<(Arc<Vec<f32>>, f64)>,
    consumed: usize,
}

struct Shared {
    n: usize,
    net: NetModel,
    state: Mutex<HashMap<u64, Round>>,
    cv: Condvar,
}

/// A communicator group of `n` ranks. Create once, then [`Group::comm`]
/// hands each worker thread its endpoint.
pub struct Group {
    shared: Arc<Shared>,
}

impl Group {
    pub fn new(n: usize, net: NetModel) -> Self {
        assert!(n >= 1);
        Group {
            shared: Arc::new(Shared {
                n,
                net,
                state: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
            }),
        }
    }

    /// Endpoint for `rank`. Each rank must be handed out exactly once;
    /// sequence numbers are tracked per-endpoint.
    pub fn comm(&self, rank: usize) -> Comm {
        assert!(rank < self.shared.n);
        Comm { rank, shared: self.shared.clone(), next_seq: 0 }
    }

    pub fn n_ranks(&self) -> usize {
        self.shared.n
    }
}

/// Per-rank communicator endpoint (the `MPI_COMM_WORLD` handle).
pub struct Comm {
    rank: usize,
    shared: Arc<Shared>,
    next_seq: u64,
}

/// In-flight non-blocking all-reduce (the `MPI_Request`).
/// Dropping without [`PendingReduce::wait`] leaks the round — like
/// losing an MPI request; debug builds assert against it.
#[must_use = "an iallreduce must be completed with wait()"]
pub struct PendingReduce {
    seq: u64,
    rank: usize,
    shared: Arc<Shared>,
    /// Virtual time at which this rank posted the operation.
    pub post_time: f64,
    done: bool,
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n_ranks(&self) -> usize {
        self.shared.n
    }

    /// The group's network cost model.
    pub fn net_model(&self) -> NetModel {
        self.shared.net
    }

    /// Non-blocking all-reduce (sum) — `MPI_Iallreduce`.
    ///
    /// `now` is this rank's virtual time at the post. The operation's
    /// completion time is `max_i(post_i) + t_AR` per the α-β model: the
    /// collective cannot start before its last participant arrives, and
    /// then takes `t_AR` — exactly the composition Eq. 14 assumes.
    pub fn iallreduce(&mut self, data: &[f32], now: f64) -> PendingReduce {
        let seq = self.next_seq;
        self.next_seq += 1;
        let n_ranks = self.shared.n;
        let mut st = self.shared.state.lock().unwrap();
        let round = st.entry(seq).or_insert_with(|| Round {
            parts: (0..n_ranks).map(|_| None).collect(),
            contributions: 0,
            max_post_time: f64::NEG_INFINITY,
            result: None,
            consumed: 0,
        });
        assert!(round.parts[self.rank].is_none(), "rank {} double-posted round {seq}", self.rank);
        round.parts[self.rank] = Some(data.to_vec());
        round.contributions += 1;
        round.max_post_time = round.max_post_time.max(now);
        if round.contributions == n_ranks {
            let t_ar = self.shared.net.allreduce_time(data.len(), n_ranks);
            let mut sum = vec![0.0f32; data.len()];
            for part in round.parts.iter_mut() {
                let part = part.take().expect("all ranks posted");
                assert_eq!(part.len(), sum.len(), "mismatched all-reduce lengths in round {seq}");
                for (a, x) in sum.iter_mut().zip(&part) {
                    *a += x;
                }
            }
            round.result = Some((Arc::new(sum), round.max_post_time + t_ar));
            self.shared.cv.notify_all();
        }
        PendingReduce {
            seq,
            rank: self.rank,
            shared: self.shared.clone(),
            post_time: now,
            done: false,
        }
    }

    /// Blocking all-reduce — `MPI_Allreduce`. Returns (sum, completion
    /// virtual time for this rank).
    pub fn allreduce(&mut self, data: &[f32], now: f64) -> (Arc<Vec<f32>>, f64) {
        self.iallreduce(data, now).wait(now)
    }

    /// Barrier: all ranks must arrive; returns each rank's exit time
    /// `max_i(arrive_i) + t_barrier`.
    pub fn barrier(&mut self, now: f64) -> f64 {
        let (_, t) = self.allreduce(&[], now);
        // allreduce of an empty payload costs α-terms only under Ring —
        // use the explicit barrier cost instead of the degenerate model.
        let mut t = t;
        if self.shared.n > 1 {
            t += self.shared.net.barrier_time(self.shared.n)
                - self.shared.net.allreduce_time(0, self.shared.n);
        }
        t
    }
}

impl PendingReduce {
    /// Complete the operation — `MPI_Wait`.
    ///
    /// `now` is the rank's virtual time when it *calls* wait (i.e. after
    /// the overlapped computation). Returns the sum and this rank's
    /// virtual time after the wait: `max(now, collective completion)` —
    /// the worker blocks only if the network is still busy, which is the
    /// whole point of the overlap (Eq. 14).
    pub fn wait(mut self, now: f64) -> (Arc<Vec<f32>>, f64) {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(round) = st.get_mut(&self.seq) {
                if let Some((sum, t_complete)) = round.result.clone() {
                    round.consumed += 1;
                    if round.consumed == self.shared.n {
                        st.remove(&self.seq);
                    }
                    self.done = true;
                    return (sum, now.max(t_complete));
                }
            }
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Non-destructive completion test — `MPI_Test` (no time advance).
    pub fn is_complete(&self) -> bool {
        let st = self.shared.state.lock().unwrap();
        st.get(&self.seq).map(|r| r.result.is_some()).unwrap_or(true)
    }
}

impl Drop for PendingReduce {
    fn drop(&mut self) {
        debug_assert!(
            self.done || std::thread::panicking(),
            "PendingReduce dropped without wait() (rank {}, seq {})",
            self.rank,
            self.seq
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_ranks<F, R>(n: usize, net: NetModel, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let group = Group::new(n, net);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let comm = group.comm(r);
                let f = f.clone();
                thread::spawn(move || f(comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        let results = spawn_ranks(4, NetModel::instant(), |mut c| {
            let mine = vec![c.rank() as f32, 1.0];
            let (sum, _) = c.allreduce(&mine, 0.0);
            sum.as_ref().clone()
        });
        for r in results {
            assert_eq!(r, vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        }
    }

    #[test]
    fn rounds_are_matched_by_sequence() {
        // Each rank runs several rounds; sums must match per-round even
        // though ranks post at different times/orders.
        let results = spawn_ranks(3, NetModel::instant(), |mut c| {
            let mut sums = Vec::new();
            for round in 0..5 {
                let mine = vec![(round * 10 + c.rank()) as f32];
                let (sum, _) = c.allreduce(&mine, round as f64);
                sums.push(sum[0]);
            }
            sums
        });
        for r in results {
            assert_eq!(r, vec![3.0, 33.0, 63.0, 93.0, 123.0]); // Σ(10r+i)
        }
    }

    #[test]
    fn completion_time_is_max_post_plus_tar() {
        // rank i posts at time i; completion must be max_post + t_AR for
        // every rank, and a rank waiting later perceives max(now, that).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e6, algo: AllReduceAlgo::Ring };
        // 1000 f32 = 4000 bytes; ring with N=4: 2*3*(4000/4)/4e6 = 1.5e-3
        let t_ar = net.allreduce_time(1000, 4);
        let results = spawn_ranks(4, net, move |mut c| {
            let post = c.rank() as f64;
            let h = c.iallreduce(&vec![1.0; 1000], post);
            let (_, t_done) = h.wait(post); // waits immediately
            t_done
        });
        let expect = 3.0 + t_ar;
        for t in results {
            assert!((t - expect).abs() < 1e-12, "t={t}, expect={expect}");
        }
    }

    #[test]
    fn overlap_hides_communication_eq14() {
        // Worker computes for t_c after posting; if t_c > t_AR the wait
        // must be free: exit time == post + t_c (Eq. 14's max).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e9, algo: AllReduceAlgo::Ring };
        let t_ar = net.allreduce_time(100_000, 2);
        assert!(t_ar > 0.0);
        let t_c = t_ar * 10.0;
        let results = spawn_ranks(2, net, move |mut c| {
            let h = c.iallreduce(&vec![1.0; 100_000], 0.0);
            let after_compute = t_c; // simulated overlapped compute
            let (_, t_done) = h.wait(after_compute);
            t_done
        });
        for t in results {
            assert!((t - t_c).abs() < 1e-15, "communication not hidden: {t} vs {t_c}");
        }
    }

    #[test]
    fn mpi_test_semantics() {
        let group = Group::new(2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let h0 = c0.iallreduce(&[1.0], 0.0);
        assert!(!h0.is_complete(), "only one rank posted");
        let h1 = c1.iallreduce(&[2.0], 0.0);
        assert!(h0.is_complete());
        let (s, _) = h0.wait(0.0);
        assert_eq!(s[0], 3.0);
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn staleness_two_outstanding_rounds() {
        // Two rounds in flight simultaneously (max-staleness 2, §V):
        // posts for round 1 happen before round 0 completes on rank 1.
        let group = Group::new(2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let a0 = c0.iallreduce(&[1.0], 0.0);
        let a1 = c0.iallreduce(&[10.0], 0.0);
        let b0 = c1.iallreduce(&[2.0], 0.0);
        let b1 = c1.iallreduce(&[20.0], 0.0);
        assert_eq!(a0.wait(0.0).0[0], 3.0);
        assert_eq!(b0.wait(0.0).0[0], 3.0);
        assert_eq!(a1.wait(0.0).0[0], 30.0);
        assert_eq!(b1.wait(0.0).0[0], 30.0);
    }

    #[test]
    fn net_model_formulas() {
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        // ring, N=8, 1M f32 (4MB): 2*7*(1e-6 + 4e6/8/1e9) = 14e-6 + 7e-3
        let t = net.allreduce_time(1_000_000, 8);
        assert!((t - (14e-6 + 7.0e-3)).abs() < 1e-9);
        // single rank: free
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        // flat is slower than ring for large payloads
        let flat = NetModel { algo: AllReduceAlgo::Flat, ..net };
        assert!(flat.allreduce_time(1_000_000, 8) > t);
        // tree beats ring on latency for tiny payloads at large N
        let tree = NetModel { algo: AllReduceAlgo::Tree, ..net };
        assert!(tree.allreduce_time(1, 64) < net.allreduce_time(1, 64));
    }

    #[test]
    fn allreduce_bandwidth_term_scales_with_size() {
        let net = NetModel::default();
        let t1 = net.allreduce_time(1_000_000, 16);
        let t2 = net.allreduce_time(2_000_000, 16);
        assert!(t2 > t1 * 1.9 && t2 < t1 * 2.1);
    }
}
