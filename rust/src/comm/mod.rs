//! Decentralized communication substrate — the simulated-MPI layer.
//!
//! The paper's algorithm needs exactly two things from MPI:
//! `MPI_Iallreduce` (non-blocking sum across all workers) and
//! `MPI_Wait`. This module provides them for N in-process workers with
//! **collective semantics identical to MPI** (every rank contributes
//! once per round, every rank receives the full payload, rounds
//! complete in sequence order) and **timing from pluggable collective
//! schedules** parameterised to Aries-like numbers (DESIGN.md §3
//! substitution table).
//!
//! Three layers:
//!
//! * [`schedule`] — the [`CollectiveSchedule`] trait and its four
//!   implementations (`Ring`, `Tree`, `FlatStar`,
//!   `Hierarchical { topology: Dragonfly }`). Every collective the
//!   substrate completes is costed by a schedule object, which
//!   decomposes its time into intra-group vs inter-group phases
//!   ([`PhaseTimes`]) — the split the control plane steers on.
//! * [`Group`] / [`Comm`] — the rendezvous-based collectives the
//!   training engines use. Data movement is exact; the reduction is
//!   performed once, in rank order, so the sum is bit-deterministic
//!   **and bit-identical across schedules** (schedules decide routing
//!   and cost, never the arithmetic). Completion *time* comes from the
//!   round's schedule, carried on the worker's virtual clock
//!   ([`crate::simtime`]); non-blocking handles capture the post time,
//!   so overlap accounting reproduces Eq. 14's `max(t_C, t_AR)`
//!   exactly. A round's schedule can be overridden per post
//!   ([`Comm::iallreduce_sched`]) — the hook the elastic control
//!   plane's `schedule_coupled` policy uses to re-pick the collective
//!   per window.
//! * [`ring`] / [`hier`] — wire-level executors (real per-edge
//!   channels): the flat ring all-reduce and the grouped
//!   Layered-SGD schedule (intra-group ring, leader ring, local
//!   broadcast). They are the differential checks that the modelled
//!   schedules correspond to real decentralized data movement, and
//!   they feed `benches/allreduce.rs`.

pub mod collectives;
pub mod hier;
pub mod ring;
pub mod schedule;
pub mod topology;

pub use schedule::{CollectiveSchedule, Link, PhaseTimes};
pub use topology::Dragonfly;

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// All-reduce schedule whose cost model [`NetModel`] applies.
///
/// This is the *config-level* description (small, `Copy`, lives in
/// [`NetModel`]); [`NetModel::schedule`] resolves it to the
/// [`CollectiveSchedule`] object that owns the cost formulas.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AllReduceAlgo {
    /// Ring: 2(N−1) steps of n/N elements — bandwidth-optimal, the
    /// algorithm Cray-mpich uses for large payloads.
    Ring,
    /// Binary tree reduce + broadcast: 2·⌈log2 N⌉ full-payload hops.
    Tree,
    /// Flat gather+scatter through rank 0 (the degenerate PS-like
    /// pattern; included for the centralised-vs-decentralised ablation).
    Flat,
    /// Hierarchical Layered-SGD schedule over a dragonfly: intra-group
    /// ring on local links, leader ring on global links, local
    /// broadcast.
    Hierarchical(Dragonfly),
}

impl AllReduceAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            AllReduceAlgo::Ring => "ring",
            AllReduceAlgo::Tree => "tree",
            AllReduceAlgo::Flat => "flat",
            AllReduceAlgo::Hierarchical(_) => "hierarchical",
        }
    }
}

/// α-β (latency-bandwidth) cost model for collectives.
///
/// Defaults approximate a Cray Aries dragonfly fabric: ~1.5 µs MPI
/// latency, ~10 GB/s effective per-node all-reduce bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency α in seconds (flat link class).
    pub alpha_s: f64,
    /// Effective bandwidth β in bytes/second (flat link class).
    pub beta_bytes_per_s: f64,
    /// Which collective schedule to cost.
    pub algo: AllReduceAlgo,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 10e9, algo: AllReduceAlgo::Ring }
    }
}

impl NetModel {
    /// An infinitely fast network (for algorithm-only studies).
    pub fn instant() -> Self {
        NetModel { alpha_s: 0.0, beta_bytes_per_s: f64::INFINITY, algo: AllReduceAlgo::Ring }
    }

    fn link(&self) -> Link {
        Link { alpha_s: self.alpha_s, beta_bytes_per_s: self.beta_bytes_per_s }
    }

    /// Resolve the configured schedule to its cost-model object.
    pub fn schedule(&self) -> Box<dyn CollectiveSchedule> {
        match self.algo {
            AllReduceAlgo::Ring => Box::new(schedule::Ring(self.link())),
            AllReduceAlgo::Tree => Box::new(schedule::Tree(self.link())),
            AllReduceAlgo::Flat => Box::new(schedule::FlatStar(self.link())),
            AllReduceAlgo::Hierarchical(topology) => {
                Box::new(schedule::Hierarchical { topology })
            }
        }
    }

    /// Per-phase time of one all-reduce of `n_elems` f32 across
    /// `n_ranks` (t_ARed(g, N) in Eq. 13/14, split local/global).
    pub fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        self.schedule().allreduce_phases(n_elems, n_ranks)
    }

    /// Total time for one all-reduce (the Eq. 13/14 t_AR).
    pub fn allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.allreduce_phases(n_elems, n_ranks).total()
    }

    /// Point-to-point time for `n_elems` f32 (used by the PS substrate:
    /// t_W2PS in Eq. 15), on the flat link class.
    pub fn ptp_time(&self, n_elems: usize) -> f64 {
        self.alpha_s + n_elems as f64 * 4.0 / self.beta_bytes_per_s
    }

    /// Topology-aware point-to-point time between two ranks: under a
    /// hierarchical schedule, ranks in the same dragonfly group talk
    /// over local links, others pay the global link; flat schedules
    /// fall back to [`NetModel::ptp_time`].
    pub fn ptp_time_between(&self, from: usize, to: usize, n_elems: usize) -> f64 {
        match self.algo {
            AllReduceAlgo::Hierarchical(d) => {
                let bytes = n_elems as f64 * 4.0;
                if d.group_of(from) == d.group_of(to) {
                    d.alpha_local_s + bytes / d.beta_local
                } else {
                    d.alpha_global_s + bytes / d.beta_global
                }
            }
            _ => self.ptp_time(n_elems),
        }
    }

    /// Barrier cost (log-tree of empty messages).
    pub fn barrier_time(&self, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            0.0
        } else {
            2.0 * (n_ranks as f64).log2().ceil() * self.alpha_s
        }
    }
}

// ---------------------------------------------------------------------------
// Rendezvous collectives
// ---------------------------------------------------------------------------

/// What a rendezvous round computes (and which schedule entry costs it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum RoundKind {
    /// Sum of equal-length contributions; everyone gets the sum.
    AllReduce,
    /// Sum of equal-length contributions; rank i keeps chunk i (the
    /// slicing happens at the caller — costed as a reduce-scatter).
    ReduceScatter,
    /// Rank-ordered concatenation of the contributions.
    AllGather,
    /// Root's contribution delivered to everyone (non-roots post `&[]`).
    Broadcast { root: usize },
}

struct Round {
    /// Per-rank contributions, reduced in rank order on completion so
    /// the result is bit-deterministic regardless of thread arrival
    /// order (float addition is not associative) — and bit-identical
    /// across schedules, which only decide the cost.
    parts: Vec<Option<Vec<f32>>>,
    contributions: usize,
    max_post_time: f64,
    kind: RoundKind,
    /// Schedule costing this round (first poster's choice; the
    /// deterministic controllers guarantee every rank picks the same).
    algo: AllReduceAlgo,
    /// Payload + sim completion time + per-phase split, set when the
    /// last rank contributes.
    result: Option<(Arc<Vec<f32>>, f64, PhaseTimes)>,
    consumed: usize,
}

impl Round {
    /// Reduce the parts per the round kind; returns (payload, phases).
    fn finish(&mut self, net: &NetModel, n_ranks: usize, seq: u64) -> (Vec<f32>, PhaseTimes) {
        let sched_net = NetModel { algo: self.algo, ..*net };
        match self.kind {
            RoundKind::AllReduce | RoundKind::ReduceScatter => {
                let len = self.parts[0].as_ref().expect("all ranks posted").len();
                let mut sum = vec![0.0f32; len];
                for part in self.parts.iter_mut() {
                    let part = part.take().expect("all ranks posted");
                    assert_eq!(
                        part.len(),
                        sum.len(),
                        "mismatched all-reduce lengths in round {seq}"
                    );
                    for (a, x) in sum.iter_mut().zip(&part) {
                        *a += x;
                    }
                }
                let phases = if self.kind == RoundKind::AllReduce {
                    sched_net.schedule().allreduce_phases(len, n_ranks)
                } else {
                    sched_net.schedule().reduce_scatter_phases(len, n_ranks)
                };
                (sum, phases)
            }
            RoundKind::AllGather => {
                let per = self.parts[0].as_ref().expect("all ranks posted").len();
                let mut out = Vec::with_capacity(per * n_ranks);
                for part in self.parts.iter_mut() {
                    let part = part.take().expect("all ranks posted");
                    assert_eq!(part.len(), per, "mismatched all-gather lengths in round {seq}");
                    out.extend_from_slice(&part);
                }
                let phases = sched_net.schedule().allgather_phases(per, n_ranks);
                (out, phases)
            }
            RoundKind::Broadcast { root } => {
                let payload = self.parts[root].take().expect("root posted");
                for p in self.parts.iter_mut() {
                    p.take();
                }
                let phases = sched_net.schedule().bcast_phases(payload.len(), n_ranks);
                (payload, phases)
            }
        }
    }
}

struct Shared {
    n: usize,
    net: NetModel,
    state: Mutex<HashMap<u64, Round>>,
    cv: Condvar,
}

/// A communicator group of `n` ranks. Create once, then [`Group::comm`]
/// hands each worker thread its endpoint.
pub struct Group {
    shared: Arc<Shared>,
}

impl Group {
    pub fn new(n: usize, net: NetModel) -> Self {
        assert!(n >= 1);
        Group {
            shared: Arc::new(Shared {
                n,
                net,
                state: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
            }),
        }
    }

    /// Endpoint for `rank`. Each rank must be handed out exactly once;
    /// sequence numbers are tracked per-endpoint.
    pub fn comm(&self, rank: usize) -> Comm {
        assert!(rank < self.shared.n);
        Comm { rank, shared: self.shared.clone(), next_seq: 0 }
    }

    pub fn n_ranks(&self) -> usize {
        self.shared.n
    }
}

/// Per-rank communicator endpoint (the `MPI_COMM_WORLD` handle).
pub struct Comm {
    rank: usize,
    shared: Arc<Shared>,
    next_seq: u64,
}

/// In-flight non-blocking collective (the `MPI_Request`).
/// Dropping without [`PendingReduce::wait`] leaks the round — like
/// losing an MPI request; debug builds assert against it.
#[must_use = "a posted collective must be completed with wait()"]
pub struct PendingReduce {
    seq: u64,
    rank: usize,
    shared: Arc<Shared>,
    /// Virtual time at which this rank posted the operation.
    pub post_time: f64,
    done: bool,
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n_ranks(&self) -> usize {
        self.shared.n
    }

    /// The group's network cost model (carrying the default schedule).
    pub fn net_model(&self) -> NetModel {
        self.shared.net
    }

    /// Post one rendezvous round of any kind. All ranks must pass the
    /// same (kind, algo) for a given sequence number — guaranteed by
    /// the control plane's determinism contract.
    pub(crate) fn post(
        &mut self,
        data: &[f32],
        now: f64,
        kind: RoundKind,
        algo: AllReduceAlgo,
    ) -> PendingReduce {
        let seq = self.next_seq;
        self.next_seq += 1;
        let n_ranks = self.shared.n;
        let mut st = self.shared.state.lock().unwrap();
        let round = st.entry(seq).or_insert_with(|| Round {
            parts: (0..n_ranks).map(|_| None).collect(),
            contributions: 0,
            max_post_time: f64::NEG_INFINITY,
            kind,
            algo,
            result: None,
            consumed: 0,
        });
        debug_assert!(
            round.kind == kind && round.algo == algo,
            "rank {} disagrees on round {seq} shape: {:?}/{:?} vs {:?}/{:?}",
            self.rank,
            round.kind,
            round.algo,
            kind,
            algo
        );
        assert!(round.parts[self.rank].is_none(), "rank {} double-posted round {seq}", self.rank);
        round.parts[self.rank] = Some(data.to_vec());
        round.contributions += 1;
        round.max_post_time = round.max_post_time.max(now);
        if round.contributions == n_ranks {
            let (payload, phases) = round.finish(&self.shared.net, n_ranks, seq);
            round.result = Some((Arc::new(payload), round.max_post_time + phases.total(), phases));
            self.shared.cv.notify_all();
        }
        PendingReduce {
            seq,
            rank: self.rank,
            shared: self.shared.clone(),
            post_time: now,
            done: false,
        }
    }

    /// Non-blocking all-reduce (sum) — `MPI_Iallreduce`, on the group's
    /// default schedule.
    ///
    /// `now` is this rank's virtual time at the post. The operation's
    /// completion time is `max_i(post_i) + t_AR` per the schedule's cost
    /// model: the collective cannot start before its last participant
    /// arrives, and then takes `t_AR` — exactly the composition Eq. 14
    /// assumes.
    pub fn iallreduce(&mut self, data: &[f32], now: f64) -> PendingReduce {
        let algo = self.shared.net.algo;
        self.post(data, now, RoundKind::AllReduce, algo)
    }

    /// Non-blocking all-reduce on an explicit schedule — the control
    /// plane's per-window schedule override. Every rank must pass the
    /// same `algo` for the same round (deterministic controllers).
    pub fn iallreduce_sched(
        &mut self,
        data: &[f32],
        now: f64,
        algo: AllReduceAlgo,
    ) -> PendingReduce {
        self.post(data, now, RoundKind::AllReduce, algo)
    }

    /// Blocking all-reduce — `MPI_Allreduce`. Returns (sum, completion
    /// virtual time for this rank).
    pub fn allreduce(&mut self, data: &[f32], now: f64) -> (Arc<Vec<f32>>, f64) {
        self.iallreduce(data, now).wait(now)
    }

    /// Blocking all-reduce on an explicit schedule; also returns the
    /// per-phase time split.
    pub fn allreduce_sched(
        &mut self,
        data: &[f32],
        now: f64,
        algo: AllReduceAlgo,
    ) -> (Arc<Vec<f32>>, f64, PhaseTimes) {
        self.iallreduce_sched(data, now, algo).wait_timed(now)
    }

    /// Barrier: all ranks must arrive; returns each rank's exit time
    /// `max_i(arrive_i) + t_barrier`.
    pub fn barrier(&mut self, now: f64) -> f64 {
        let (_, t) = self.allreduce(&[], now);
        // allreduce of an empty payload costs α-terms only under Ring —
        // use the explicit barrier cost instead of the degenerate model.
        let mut t = t;
        if self.shared.n > 1 {
            t += self.shared.net.barrier_time(self.shared.n)
                - self.shared.net.allreduce_time(0, self.shared.n);
        }
        t
    }
}

impl PendingReduce {
    /// Complete the operation — `MPI_Wait` — returning the payload,
    /// this rank's virtual time after the wait, and the collective's
    /// per-phase time split.
    ///
    /// `now` is the rank's virtual time when it *calls* wait (i.e. after
    /// the overlapped computation). The returned time is
    /// `max(now, collective completion)` — the worker blocks only if
    /// the network is still busy, which is the whole point of the
    /// overlap (Eq. 14).
    pub fn wait_timed(mut self, now: f64) -> (Arc<Vec<f32>>, f64, PhaseTimes) {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(round) = st.get_mut(&self.seq) {
                if let Some((sum, t_complete, phases)) = round.result.clone() {
                    round.consumed += 1;
                    if round.consumed == self.shared.n {
                        st.remove(&self.seq);
                    }
                    self.done = true;
                    return (sum, now.max(t_complete), phases);
                }
            }
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Complete the operation — `MPI_Wait` (payload + exit time only).
    pub fn wait(self, now: f64) -> (Arc<Vec<f32>>, f64) {
        let (sum, t, _) = self.wait_timed(now);
        (sum, t)
    }

    /// Non-destructive completion test — `MPI_Test` (no time advance).
    pub fn is_complete(&self) -> bool {
        let st = self.shared.state.lock().unwrap();
        st.get(&self.seq).map(|r| r.result.is_some()).unwrap_or(true)
    }
}

impl Drop for PendingReduce {
    fn drop(&mut self) {
        debug_assert!(
            self.done || std::thread::panicking(),
            "PendingReduce dropped without wait() (rank {}, seq {})",
            self.rank,
            self.seq
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_ranks<F, R>(n: usize, net: NetModel, f: F) -> Vec<R>
    where
        F: Fn(Comm) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let group = Group::new(n, net);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let comm = group.comm(r);
                let f = f.clone();
                thread::spawn(move || f(comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        let results = spawn_ranks(4, NetModel::instant(), |mut c| {
            let mine = vec![c.rank() as f32, 1.0];
            let (sum, _) = c.allreduce(&mine, 0.0);
            sum.as_ref().clone()
        });
        for r in results {
            assert_eq!(r, vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        }
    }

    #[test]
    fn rounds_are_matched_by_sequence() {
        // Each rank runs several rounds; sums must match per-round even
        // though ranks post at different times/orders.
        let results = spawn_ranks(3, NetModel::instant(), |mut c| {
            let mut sums = Vec::new();
            for round in 0..5 {
                let mine = vec![(round * 10 + c.rank()) as f32];
                let (sum, _) = c.allreduce(&mine, round as f64);
                sums.push(sum[0]);
            }
            sums
        });
        for r in results {
            assert_eq!(r, vec![3.0, 33.0, 63.0, 93.0, 123.0]); // Σ(10r+i)
        }
    }

    #[test]
    fn completion_time_is_max_post_plus_tar() {
        // rank i posts at time i; completion must be max_post + t_AR for
        // every rank, and a rank waiting later perceives max(now, that).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e6, algo: AllReduceAlgo::Ring };
        // 1000 f32 = 4000 bytes; ring with N=4: 2*3*(4000/4)/4e6 = 1.5e-3
        let t_ar = net.allreduce_time(1000, 4);
        let results = spawn_ranks(4, net, move |mut c| {
            let post = c.rank() as f64;
            let h = c.iallreduce(&vec![1.0; 1000], post);
            let (_, t_done) = h.wait(post); // waits immediately
            t_done
        });
        let expect = 3.0 + t_ar;
        for t in results {
            assert!((t - expect).abs() < 1e-12, "t={t}, expect={expect}");
        }
    }

    #[test]
    fn overlap_hides_communication_eq14() {
        // Worker computes for t_c after posting; if t_c > t_AR the wait
        // must be free: exit time == post + t_c (Eq. 14's max).
        let net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 4e9, algo: AllReduceAlgo::Ring };
        let t_ar = net.allreduce_time(100_000, 2);
        assert!(t_ar > 0.0);
        let t_c = t_ar * 10.0;
        let results = spawn_ranks(2, net, move |mut c| {
            let h = c.iallreduce(&vec![1.0; 100_000], 0.0);
            let after_compute = t_c; // simulated overlapped compute
            let (_, t_done) = h.wait(after_compute);
            t_done
        });
        for t in results {
            assert!((t - t_c).abs() < 1e-15, "communication not hidden: {t} vs {t_c}");
        }
    }

    #[test]
    fn mpi_test_semantics() {
        let group = Group::new(2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let h0 = c0.iallreduce(&[1.0], 0.0);
        assert!(!h0.is_complete(), "only one rank posted");
        let h1 = c1.iallreduce(&[2.0], 0.0);
        assert!(h0.is_complete());
        let (s, _) = h0.wait(0.0);
        assert_eq!(s[0], 3.0);
        h1.wait(0.0).0.as_ref();
    }

    #[test]
    fn staleness_two_outstanding_rounds() {
        // Two rounds in flight simultaneously (max-staleness 2, §V):
        // posts for round 1 happen before round 0 completes on rank 1.
        let group = Group::new(2, NetModel::instant());
        let mut c0 = group.comm(0);
        let mut c1 = group.comm(1);
        let a0 = c0.iallreduce(&[1.0], 0.0);
        let a1 = c0.iallreduce(&[10.0], 0.0);
        let b0 = c1.iallreduce(&[2.0], 0.0);
        let b1 = c1.iallreduce(&[20.0], 0.0);
        assert_eq!(a0.wait(0.0).0[0], 3.0);
        assert_eq!(b0.wait(0.0).0[0], 3.0);
        assert_eq!(a1.wait(0.0).0[0], 30.0);
        assert_eq!(b1.wait(0.0).0[0], 30.0);
    }

    #[test]
    fn net_model_formulas() {
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        // ring, N=8, 1M f32 (4MB): 2*7*(1e-6 + 4e6/8/1e9) = 14e-6 + 7e-3
        let t = net.allreduce_time(1_000_000, 8);
        assert!((t - (14e-6 + 7.0e-3)).abs() < 1e-9);
        // single rank: free
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        // flat is slower than ring for large payloads
        let flat = NetModel { algo: AllReduceAlgo::Flat, ..net };
        assert!(flat.allreduce_time(1_000_000, 8) > t);
        // tree beats ring on latency for tiny payloads at large N
        let tree = NetModel { algo: AllReduceAlgo::Tree, ..net };
        assert!(tree.allreduce_time(1, 64) < net.allreduce_time(1, 64));
    }

    #[test]
    fn allreduce_bandwidth_term_scales_with_size() {
        let net = NetModel::default();
        let t1 = net.allreduce_time(1_000_000, 16);
        let t2 = net.allreduce_time(2_000_000, 16);
        assert!(t2 > t1 * 1.9 && t2 < t1 * 2.1);
    }

    #[test]
    fn hierarchical_rounds_cost_hierarchical_time_and_sum_identically() {
        // Same inputs through a Ring group and a Hierarchical group:
        // sums bit-identical (schedules never touch the arithmetic),
        // completion times from the respective schedules.
        let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Dragonfly::default() };
        let flat = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        let hier = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..flat };
        let run = |net: NetModel| {
            spawn_ranks(4, net, |mut c| {
                let mine: Vec<f32> =
                    (0..100).map(|i| (i as f32 + 1.0) * 0.37 + c.rank() as f32).collect();
                let (sum, t) = c.allreduce(&mine, 0.0);
                (sum.as_ref().clone(), t)
            })
        };
        let ring_out = run(flat);
        let hier_out = run(hier);
        for ((rs, rt), (hs, ht)) in ring_out.iter().zip(&hier_out) {
            assert_eq!(rs, hs, "schedules changed the sum");
            assert!((rt - flat.allreduce_time(100, 4)).abs() < 1e-15);
            assert!((ht - hier.allreduce_time(100, 4)).abs() < 1e-15);
        }
        assert_ne!(ring_out[0].1, hier_out[0].1, "schedules should cost differently");
    }

    #[test]
    fn per_round_schedule_override() {
        // A group defaulting to Ring can run one round hierarchically;
        // the phase split must come back through wait_timed.
        let d = Dragonfly::default();
        let results = spawn_ranks(4, NetModel::default(), move |mut c| {
            let h = c.iallreduce_sched(&[1.0; 64], 0.0, AllReduceAlgo::Hierarchical(d));
            let (sum, t, phases) = h.wait_timed(0.0);
            (sum[0], t, phases)
        });
        let expect = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let want = expect.allreduce_phases(64, 4);
        for (s, t, phases) in results {
            assert_eq!(s, 4.0);
            assert_eq!(phases, want);
            assert!((t - want.total()).abs() < 1e-15);
        }
    }

    #[test]
    fn ptp_time_between_uses_topology() {
        let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Dragonfly::default() };
        let net = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let local = net.ptp_time_between(0, 1, 1000); // same group
        let global = net.ptp_time_between(0, 2, 1000); // across groups
        assert!(global > local, "{global} vs {local}");
        // flat schedules ignore rank placement
        let flat = NetModel::default();
        assert_eq!(flat.ptp_time_between(0, 3, 1000), flat.ptp_time(1000));
    }
}
