//! Network topologies: the paper's testbed is a Cray XC with Aries
//! routers in a **dragonfly** topology (§IV-B). [`Dragonfly`] describes
//! the two-level fabric — fast electrical links within a group, slower
//! tapered optics between groups — and is the parameter block of the
//! first-class [`Hierarchical`](super::schedule::Hierarchical)
//! collective schedule (intra-group ring → leader ring → local
//! broadcast, per Layered SGD).
//!
//! Historically this module *flattened* the hierarchical schedule back
//! into an effective α-β pair so the engines (which only understood the
//! flat model) could approximate it; that hack is retired — engines now
//! take the schedule itself via `AllReduceAlgo::Hierarchical` — but
//! [`Dragonfly::effective_net_model`] is kept as an explicit ablation
//! utility (how wrong is the flattening?) for the comm benches.

use super::schedule::{CollectiveSchedule, Hierarchical, PhaseTimes};
use super::{AllReduceAlgo, NetModel};

/// A two-level dragonfly abstraction: `groups` fully-connected groups of
/// `nodes_per_group` nodes; intra-group links are fast (electrical),
/// inter-group links slower (optical, tapered).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dragonfly {
    pub groups: usize,
    pub nodes_per_group: usize,
    /// Intra-group latency / bandwidth.
    pub alpha_local_s: f64,
    pub beta_local: f64,
    /// Inter-group latency / bandwidth (per global link).
    pub alpha_global_s: f64,
    pub beta_global: f64,
}

impl Default for Dragonfly {
    fn default() -> Self {
        // Aries-like: ~1.2 µs within a group, ~2.2 µs across optics;
        // 14 GB/s electrical, 4.7 GB/s per-node tapered global.
        Dragonfly {
            groups: 4,
            nodes_per_group: 32,
            alpha_local_s: 1.2e-6,
            beta_local: 14e9,
            alpha_global_s: 2.2e-6,
            beta_global: 4.7e9,
        }
    }
}

impl Dragonfly {
    pub fn n_nodes(&self) -> usize {
        self.groups * self.nodes_per_group
    }

    /// The canonical (groups, nodes_per_group) shape for `n` nodes:
    /// √n groups, rounded up. Shared by [`Dragonfly::for_nodes`] and
    /// [`Dragonfly::refit`] so a refitted epoch topology always agrees
    /// with what a fresh run of the same world size would derive.
    fn shape_for(n: usize) -> (usize, usize) {
        let groups = ((n as f64).sqrt().ceil() as usize).max(1);
        (groups, n.div_ceil(groups).max(1))
    }

    /// Shape a dragonfly around `n` nodes (√n groups, rounded up).
    pub fn for_nodes(n: usize) -> Self {
        let (groups, nodes_per_group) = Self::shape_for(n);
        Dragonfly { groups, nodes_per_group, ..Dragonfly::default() }
    }

    /// Re-derive the group shape for a new world size while keeping
    /// this fabric's link parameters — the membership-epoch transition:
    /// when ranks leave or join, the dragonfly groups are recomputed
    /// from the *current* N, but the optics stay the optics.
    pub fn refit(&self, n: usize) -> Self {
        let (groups, nodes_per_group) = Self::shape_for(n);
        Dragonfly { groups, nodes_per_group, ..*self }
    }

    /// The group a rank lives in (ranks are laid out group-contiguous).
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.nodes_per_group.max(1)
    }

    /// The number of groups spanned by `n_ranks` ranks.
    pub fn groups_spanned(&self, n_ranks: usize) -> usize {
        n_ranks.div_ceil(self.nodes_per_group.max(1)).max(1)
    }

    /// This topology's hierarchical schedule object.
    pub fn schedule(&self) -> Hierarchical {
        Hierarchical { topology: *self }
    }

    /// Hierarchical all-reduce cost, split into local vs global phases:
    /// ring reduce-scatter + all-gather within each group (local
    /// links), then a ring across group leaders on the reduced payload
    /// (global links), then local broadcast.
    pub fn hierarchical_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        self.schedule().allreduce_phases(n_elems, n_ranks)
    }

    /// Total hierarchical all-reduce cost (the sum of the phases).
    pub fn hierarchical_allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.hierarchical_phases(n_elems, n_ranks).total()
    }

    /// A flat [`NetModel`] with effective parameters matched to this
    /// dragonfly at a given scale.
    ///
    /// **Ablation-only.** The engines used to need this flattening to
    /// run on a dragonfly at all; they now take
    /// `AllReduceAlgo::Hierarchical(topology)` directly, and the only
    /// remaining consumer is the bench quantifying what the flattening
    /// loses. A single rank has no collective to match (`t = 0` would
    /// solve to a bogus β), so it degenerates to an instant network.
    pub fn effective_net_model(&self, n_elems: usize, n_ranks: usize) -> NetModel {
        if n_ranks <= 1 {
            return NetModel::instant();
        }
        let t = self.hierarchical_allreduce_time(n_elems, n_ranks);
        // Solve the flat-ring formula for β with the default α:
        //   t = 2(N−1)(α + b/N/β)  ⇒  β = b/N / (t/(2(N−1)) − α)
        let alpha = self.alpha_local_s;
        let n = n_ranks as f64;
        let bytes = n_elems as f64 * 4.0;
        let per_step = (t / (2.0 * (n - 1.0)) - alpha).max(1e-12);
        NetModel {
            alpha_s: alpha,
            beta_bytes_per_s: bytes / n / per_step,
            algo: AllReduceAlgo::Ring,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_group_is_pure_local_ring() {
        let d = Dragonfly { groups: 1, nodes_per_group: 8, ..Dragonfly::default() };
        let t = d.hierarchical_allreduce_time(1_000_000, 8);
        let local_ring =
            2.0 * 7.0 * (d.alpha_local_s + 4e6 / 8.0 / d.beta_local);
        // plus the local broadcast term
        assert!(t >= local_ring);
        assert!(t < local_ring * 1.5);
        // and nothing crossed a global link
        assert_eq!(d.hierarchical_phases(1_000_000, 8).global_s, 0.0);
    }

    #[test]
    fn cross_group_costs_more_than_local() {
        let d = Dragonfly::default();
        let within = d.hierarchical_allreduce_time(1_000_000, d.nodes_per_group);
        let across = d.hierarchical_allreduce_time(1_000_000, d.n_nodes());
        assert!(across > within, "{across} vs {within}");
    }

    #[test]
    fn monotone_in_payload_and_ranks() {
        let d = Dragonfly::default();
        assert!(
            d.hierarchical_allreduce_time(2_000_000, 64)
                > d.hierarchical_allreduce_time(1_000_000, 64)
        );
        assert!(
            d.hierarchical_allreduce_time(1_000_000, 128)
                > d.hierarchical_allreduce_time(1_000_000, 16)
        );
    }

    #[test]
    fn for_nodes_covers_request() {
        let d = Dragonfly::for_nodes(100);
        assert!(d.n_nodes() >= 100);
    }

    #[test]
    fn refit_keeps_links_and_recomputes_shape() {
        let d = Dragonfly { beta_global: 9.9e9, ..Dragonfly::for_nodes(64) };
        let r = d.refit(48);
        assert!(r.n_nodes() >= 48);
        assert_eq!(r.beta_global, 9.9e9, "link parameters must survive the refit");
        assert_eq!(r.groups, Dragonfly::for_nodes(48).groups);
        // growing back re-derives again
        assert!(d.refit(80).n_nodes() >= 80);
    }

    #[test]
    fn group_mapping_is_contiguous() {
        let d = Dragonfly { groups: 3, nodes_per_group: 4, ..Dragonfly::default() };
        assert_eq!(d.group_of(0), 0);
        assert_eq!(d.group_of(3), 0);
        assert_eq!(d.group_of(4), 1);
        assert_eq!(d.group_of(11), 2);
        assert_eq!(d.groups_spanned(1), 1);
        assert_eq!(d.groups_spanned(4), 1);
        assert_eq!(d.groups_spanned(5), 2);
        assert_eq!(d.groups_spanned(12), 3);
    }

    #[test]
    fn effective_model_matches_hierarchical_time() {
        let d = Dragonfly::default();
        let (elems, ranks) = (1_000_000, 64);
        let t_hier = d.hierarchical_allreduce_time(elems, ranks);
        let net = d.effective_net_model(elems, ranks);
        let t_flat = net.allreduce_time(elems, ranks);
        assert!((t_flat - t_hier).abs() / t_hier < 0.05, "{t_flat} vs {t_hier}");
    }

    #[test]
    fn effective_model_single_rank_is_instant() {
        // Regression: n_ranks = 1 used to solve the flat-ring formula
        // with (n − 1) clamped to 1, producing a bogus β from t = 0.
        let net = Dragonfly::default().effective_net_model(1_000_000, 1);
        assert_eq!(net.alpha_s, 0.0);
        assert!(net.beta_bytes_per_s.is_infinite());
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        // and it must stay harmless if someone costs a bigger group on it
        assert_eq!(net.allreduce_time(1_000_000, 8), 0.0);
    }

    #[test]
    fn single_rank_free() {
        assert_eq!(Dragonfly::default().hierarchical_allreduce_time(1000, 1), 0.0);
    }
}
