//! Network topologies: the paper's testbed is a Cray XC with Aries
//! routers in a **dragonfly** topology (§IV-B). [`Dragonfly`] describes
//! the two-level fabric — fast electrical links within a group, slower
//! tapered optics between groups — and is the parameter block of the
//! first-class [`Hierarchical`](super::schedule::Hierarchical)
//! collective schedule (intra-group ring → leader ring → local
//! broadcast, per Layered SGD).
//!
//! ## Global-link contention
//!
//! Real dragonflies do not give every inter-group flow a dedicated
//! optic: each group owns [`Dragonfly::global_taper`] global links, and
//! every flow that crosses the group boundary *shares* them.
//! [`GlobalContention`] is the shared pricing rule — `flows` concurrent
//! flows over `links` links divide the per-link bandwidth β by
//! `max(1, flows/links)` while the latency α is untouched (contention
//! queues bytes, not handshakes). The hierarchical schedule prices its
//! leader phases through it (see
//! [`super::schedule::LEADER_RING_FLOWS`]), the wire-level executor
//! prices its measured volumes through it
//! ([`super::hier::HierVolume::priced`]), and the parameter-server
//! engines price worker↔PS crossings through it
//! ([`super::NetModel::ptp_time_between_flows`]) — one model, three
//! consumers, so modelled and wire-level t_AR agree under load.
//!
//! Historically this module *flattened* the hierarchical schedule back
//! into an effective α-β pair so the engines (which only understood the
//! flat model) could approximate it; that hack is retired — engines now
//! take the schedule itself via `AllReduceAlgo::Hierarchical` — but
//! [`Dragonfly::effective_net_model`] is kept as an explicit ablation
//! utility (how wrong is the flattening?) for the comm benches.

use super::schedule::{CollectiveSchedule, Hierarchical, Link, PhaseTimes};
use super::{AllReduceAlgo, NetModel};

/// Contention on one dragonfly group's tapered global links: `flows`
/// concurrent inter-group flows sharing `links` optics. Up to `links`
/// flows each get a full-bandwidth link; beyond that they divide the
/// capacity fairly. α is a per-message handshake, not a capacity — it
/// never contends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalContention {
    /// Global links the group owns (the taper).
    pub links: usize,
    /// Concurrent flows crossing the group boundary.
    pub flows: usize,
}

impl GlobalContention {
    /// A single flow on its own optic — the dedicated baseline. One
    /// concurrent flow never contends, whatever the taper.
    pub fn dedicated() -> Self {
        GlobalContention { links: 1, flows: 1 }
    }

    /// Bandwidth-division factor ≥ 1: `flows / links` once the links
    /// are oversubscribed, 1 while every flow still has its own optic.
    pub fn slowdown(&self) -> f64 {
        let links = self.links.max(1) as f64;
        let flows = self.flows.max(1) as f64;
        (flows / links).max(1.0)
    }

    /// The effective per-flow link: β divided by [`Self::slowdown`],
    /// α unchanged.
    pub fn contend(&self, link: Link) -> Link {
        Link {
            alpha_s: link.alpha_s,
            beta_bytes_per_s: link.beta_bytes_per_s / self.slowdown(),
        }
    }
}

/// A two-level dragonfly abstraction: `groups` fully-connected groups of
/// `nodes_per_group` nodes; intra-group links are fast (electrical),
/// inter-group links slower (optical, tapered) and **shared** — see
/// [`GlobalContention`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dragonfly {
    pub groups: usize,
    pub nodes_per_group: usize,
    /// Intra-group latency / bandwidth.
    pub alpha_local_s: f64,
    pub beta_local: f64,
    /// Inter-group latency / bandwidth (per global link).
    pub alpha_global_s: f64,
    pub beta_global: f64,
    /// Global links per group (the taper). The hierarchical leader
    /// phases keep [`super::schedule::LEADER_RING_FLOWS`] flows in
    /// flight per group, so the default of 2 prices them on dedicated
    /// optics (bit-identical to the pre-contention model);
    /// `global_taper = 1` oversubscribes the group boundary and halves
    /// the leader ring's effective β.
    pub global_taper: usize,
}

impl Default for Dragonfly {
    fn default() -> Self {
        // Aries-like: ~1.2 µs within a group, ~2.2 µs across optics;
        // 14 GB/s electrical, 4.7 GB/s per-node tapered global, two
        // global links per group (leader traffic rides dedicated).
        Dragonfly {
            groups: 4,
            nodes_per_group: 32,
            alpha_local_s: 1.2e-6,
            beta_local: 14e9,
            alpha_global_s: 2.2e-6,
            beta_global: 4.7e9,
            global_taper: 2,
        }
    }
}

impl Dragonfly {
    pub fn n_nodes(&self) -> usize {
        self.groups * self.nodes_per_group
    }

    /// The canonical (groups, nodes_per_group) shape for `n` nodes:
    /// √n groups, rounded up. Shared by [`Dragonfly::for_nodes`] and
    /// [`Dragonfly::refit`] so a refitted epoch topology always agrees
    /// with what a fresh run of the same world size would derive.
    fn shape_for(n: usize) -> (usize, usize) {
        let groups = ((n as f64).sqrt().ceil() as usize).max(1);
        (groups, n.div_ceil(groups).max(1))
    }

    /// Shape a dragonfly around `n` nodes (√n groups, rounded up).
    pub fn for_nodes(n: usize) -> Self {
        let (groups, nodes_per_group) = Self::shape_for(n);
        Dragonfly { groups, nodes_per_group, ..Dragonfly::default() }
    }

    /// Re-derive the group shape for a new world size while keeping
    /// this fabric's link parameters — the membership-epoch transition:
    /// when ranks leave or join, the dragonfly groups are recomputed
    /// from the *current* N, but the optics stay the optics.
    pub fn refit(&self, n: usize) -> Self {
        let (groups, nodes_per_group) = Self::shape_for(n);
        Dragonfly { groups, nodes_per_group, ..*self }
    }

    /// The group a rank lives in (ranks are laid out group-contiguous).
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.nodes_per_group.max(1)
    }

    /// The number of groups spanned by `n_ranks` ranks.
    pub fn groups_spanned(&self, n_ranks: usize) -> usize {
        n_ranks.div_ceil(self.nodes_per_group.max(1)).max(1)
    }

    /// The intra-group (electrical) α-β link.
    pub fn local_link(&self) -> Link {
        Link { alpha_s: self.alpha_local_s, beta_bytes_per_s: self.beta_local }
    }

    /// One inter-group (optical) α-β link, uncontended.
    pub fn global_link(&self) -> Link {
        Link { alpha_s: self.alpha_global_s, beta_bytes_per_s: self.beta_global }
    }

    /// The contention state of one group's global links under `flows`
    /// concurrent inter-group flows.
    pub fn contention(&self, flows: usize) -> GlobalContention {
        GlobalContention { links: self.global_taper, flows }
    }

    /// The effective per-flow global link under `flows` concurrent
    /// inter-group flows — [`Dragonfly::global_link`] with β divided by
    /// the [`GlobalContention::slowdown`].
    pub fn contended_global_link(&self, flows: usize) -> Link {
        self.contention(flows).contend(self.global_link())
    }

    /// This topology's hierarchical schedule object.
    pub fn schedule(&self) -> Hierarchical {
        Hierarchical { topology: *self }
    }

    /// Hierarchical all-reduce cost, split into local vs global phases:
    /// ring reduce-scatter + all-gather within each group (local
    /// links), then a ring across group leaders on the reduced payload
    /// (global links), then local broadcast.
    pub fn hierarchical_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        self.schedule().allreduce_phases(n_elems, n_ranks)
    }

    /// Total hierarchical all-reduce cost (the sum of the phases).
    pub fn hierarchical_allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.hierarchical_phases(n_elems, n_ranks).total()
    }

    /// A flat [`NetModel`] with effective parameters matched to this
    /// dragonfly at a given scale.
    ///
    /// **Ablation-only.** The engines used to need this flattening to
    /// run on a dragonfly at all; they now take
    /// `AllReduceAlgo::Hierarchical(topology)` directly, and the only
    /// remaining consumer is the bench quantifying what the flattening
    /// loses. A single rank has no collective to match (`t = 0` would
    /// solve to a bogus β), so it degenerates to an instant network.
    pub fn effective_net_model(&self, n_elems: usize, n_ranks: usize) -> NetModel {
        if n_ranks <= 1 {
            return NetModel::instant();
        }
        let t = self.hierarchical_allreduce_time(n_elems, n_ranks);
        // Solve the flat-ring formula for β with the default α:
        //   t = 2(N−1)(α + b/N/β)  ⇒  β = b/N / (t/(2(N−1)) − α)
        let alpha = self.alpha_local_s;
        let n = n_ranks as f64;
        let bytes = n_elems as f64 * 4.0;
        let per_step = (t / (2.0 * (n - 1.0)) - alpha).max(1e-12);
        NetModel {
            alpha_s: alpha,
            beta_bytes_per_s: bytes / n / per_step,
            algo: AllReduceAlgo::Ring,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LEADER_RING_FLOWS;

    #[test]
    fn single_group_is_pure_local_ring() {
        let d = Dragonfly { groups: 1, nodes_per_group: 8, ..Dragonfly::default() };
        let t = d.hierarchical_allreduce_time(1_000_000, 8);
        let local_ring =
            2.0 * 7.0 * (d.alpha_local_s + 4e6 / 8.0 / d.beta_local);
        // plus the local broadcast term
        assert!(t >= local_ring);
        assert!(t < local_ring * 1.5);
        // and nothing crossed a global link
        assert_eq!(d.hierarchical_phases(1_000_000, 8).global_s, 0.0);
    }

    #[test]
    fn cross_group_costs_more_than_local() {
        let d = Dragonfly::default();
        let within = d.hierarchical_allreduce_time(1_000_000, d.nodes_per_group);
        let across = d.hierarchical_allreduce_time(1_000_000, d.n_nodes());
        assert!(across > within, "{across} vs {within}");
    }

    #[test]
    fn monotone_in_payload_and_ranks() {
        let d = Dragonfly::default();
        assert!(
            d.hierarchical_allreduce_time(2_000_000, 64)
                > d.hierarchical_allreduce_time(1_000_000, 64)
        );
        assert!(
            d.hierarchical_allreduce_time(1_000_000, 128)
                > d.hierarchical_allreduce_time(1_000_000, 16)
        );
    }

    #[test]
    fn for_nodes_covers_request() {
        let d = Dragonfly::for_nodes(100);
        assert!(d.n_nodes() >= 100);
    }

    #[test]
    fn refit_keeps_links_and_recomputes_shape() {
        let d = Dragonfly { beta_global: 9.9e9, global_taper: 1, ..Dragonfly::for_nodes(64) };
        let r = d.refit(48);
        assert!(r.n_nodes() >= 48);
        assert_eq!(r.beta_global, 9.9e9, "link parameters must survive the refit");
        assert_eq!(r.global_taper, 1, "the taper is a link parameter: it survives the refit");
        assert_eq!(r.groups, Dragonfly::for_nodes(48).groups);
        // growing back re-derives again, still carrying the taper
        assert!(d.refit(80).n_nodes() >= 80);
        assert_eq!(d.refit(80).global_taper, 1);
    }

    #[test]
    fn refit_chain_across_membership_transitions_preserves_contention_params() {
        // The elastic-membership path refits at every epoch (64 → 48 →
        // 80); the contention parameters must ride through the whole
        // chain, and the contended pricing must stay consistent with a
        // fresh topology of the same shape.
        let d0 = Dragonfly {
            beta_global: 3.3e9,
            alpha_global_s: 5e-6,
            global_taper: 1,
            ..Dragonfly::for_nodes(64)
        };
        let d1 = d0.refit(48);
        let d2 = d1.refit(80);
        for d in [d1, d2] {
            assert_eq!(d.global_taper, 1);
            assert_eq!(d.beta_global, 3.3e9);
            assert_eq!(d.alpha_global_s, 5e-6);
        }
        let fresh = Dragonfly {
            beta_global: 3.3e9,
            alpha_global_s: 5e-6,
            global_taper: 1,
            ..Dragonfly::for_nodes(80)
        };
        assert_eq!(d2, fresh, "refit chain must agree with a fresh derivation");
    }

    #[test]
    fn contention_divides_bandwidth_never_latency() {
        let link = Link { alpha_s: 2e-6, beta_bytes_per_s: 4e9 };
        // one flow never contends, whatever the taper
        for links in [1usize, 2, 8] {
            let c = GlobalContention { links, flows: 1 };
            assert_eq!(c.slowdown(), 1.0);
            assert_eq!(c.contend(link), link);
        }
        assert_eq!(GlobalContention::dedicated().contend(link), link);
        // flows within the taper ride dedicated links
        assert_eq!(GlobalContention { links: 4, flows: 4 }.slowdown(), 1.0);
        // oversubscription divides β fairly, α unchanged
        let c = GlobalContention { links: 1, flows: 2 };
        assert_eq!(c.slowdown(), 2.0);
        let eff = c.contend(link);
        assert_eq!(eff.alpha_s, link.alpha_s);
        assert_eq!(eff.beta_bytes_per_s, link.beta_bytes_per_s / 2.0);
        // degenerate inputs clamp instead of dividing by zero
        assert_eq!(GlobalContention { links: 0, flows: 0 }.slowdown(), 1.0);
    }

    #[test]
    fn contended_global_link_prices_the_taper() {
        let d = Dragonfly { global_taper: 2, ..Dragonfly::default() };
        assert_eq!(d.contended_global_link(1), d.global_link());
        assert_eq!(d.contended_global_link(2), d.global_link());
        let over = d.contended_global_link(4);
        assert_eq!(over.alpha_s, d.alpha_global_s);
        assert_eq!(over.beta_bytes_per_s, d.beta_global / 2.0);
    }

    #[test]
    fn default_taper_keeps_leader_ring_dedicated() {
        // The compatibility anchor: at the default taper the leader
        // ring's LEADER_RING_FLOWS concurrent flows see no slowdown, so
        // every pre-contention hierarchical cost is reproduced exactly.
        let d = Dragonfly::default();
        assert!(d.global_taper >= LEADER_RING_FLOWS);
        assert_eq!(d.contention(LEADER_RING_FLOWS).slowdown(), 1.0);
    }

    #[test]
    fn group_mapping_is_contiguous() {
        let d = Dragonfly { groups: 3, nodes_per_group: 4, ..Dragonfly::default() };
        assert_eq!(d.group_of(0), 0);
        assert_eq!(d.group_of(3), 0);
        assert_eq!(d.group_of(4), 1);
        assert_eq!(d.group_of(11), 2);
        assert_eq!(d.groups_spanned(1), 1);
        assert_eq!(d.groups_spanned(4), 1);
        assert_eq!(d.groups_spanned(5), 2);
        assert_eq!(d.groups_spanned(12), 3);
    }

    #[test]
    fn effective_model_matches_hierarchical_time() {
        let d = Dragonfly::default();
        let (elems, ranks) = (1_000_000, 64);
        let t_hier = d.hierarchical_allreduce_time(elems, ranks);
        let net = d.effective_net_model(elems, ranks);
        let t_flat = net.allreduce_time(elems, ranks);
        assert!((t_flat - t_hier).abs() / t_hier < 0.05, "{t_flat} vs {t_hier}");
    }

    #[test]
    fn effective_model_single_rank_is_instant() {
        // Regression: n_ranks = 1 used to solve the flat-ring formula
        // with (n − 1) clamped to 1, producing a bogus β from t = 0.
        let net = Dragonfly::default().effective_net_model(1_000_000, 1);
        assert_eq!(net.alpha_s, 0.0);
        assert!(net.beta_bytes_per_s.is_infinite());
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        // and it must stay harmless if someone costs a bigger group on it
        assert_eq!(net.allreduce_time(1_000_000, 8), 0.0);
    }

    #[test]
    fn single_rank_free() {
        assert_eq!(Dragonfly::default().hierarchical_allreduce_time(1000, 1), 0.0);
    }
}
