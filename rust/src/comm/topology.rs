//! Network topologies: the paper's testbed is a Cray XC with Aries
//! routers in a **dragonfly** topology (§IV-B). This module refines the
//! flat α-β model with topology-aware link costs and a hierarchical
//! (intra-group reduce → inter-group exchange → intra-group broadcast)
//! all-reduce schedule, used by the comm benches as an ablation against
//! the flat ring model.

use super::{AllReduceAlgo, NetModel};

/// A two-level dragonfly abstraction: `groups` fully-connected groups of
/// `nodes_per_group` nodes; intra-group links are fast (electrical),
/// inter-group links slower (optical, tapered).
#[derive(Debug, Clone, Copy)]
pub struct Dragonfly {
    pub groups: usize,
    pub nodes_per_group: usize,
    /// Intra-group latency / bandwidth.
    pub alpha_local_s: f64,
    pub beta_local: f64,
    /// Inter-group latency / bandwidth (per global link).
    pub alpha_global_s: f64,
    pub beta_global: f64,
}

impl Default for Dragonfly {
    fn default() -> Self {
        // Aries-like: ~1.2 µs within a group, ~2.2 µs across optics;
        // 14 GB/s electrical, 4.7 GB/s per-node tapered global.
        Dragonfly {
            groups: 4,
            nodes_per_group: 32,
            alpha_local_s: 1.2e-6,
            beta_local: 14e9,
            alpha_global_s: 2.2e-6,
            beta_global: 4.7e9,
        }
    }
}

impl Dragonfly {
    pub fn n_nodes(&self) -> usize {
        self.groups * self.nodes_per_group
    }

    /// Shape a dragonfly around `n` nodes (√n groups, rounded up).
    pub fn for_nodes(n: usize) -> Self {
        let mut d = Dragonfly::default();
        let groups = (n as f64).sqrt().ceil() as usize;
        d.groups = groups.max(1);
        d.nodes_per_group = n.div_ceil(d.groups).max(1);
        d
    }

    /// Hierarchical all-reduce cost: ring reduce-scatter + all-gather
    /// within each group (local links), then a ring across group leaders
    /// on the reduced payload (global links), then local broadcast.
    pub fn hierarchical_allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            return 0.0;
        }
        let bytes = n_elems as f64 * 4.0;
        let local_ranks = self.nodes_per_group.min(n_ranks) as f64;
        let n_groups = n_ranks.div_ceil(self.nodes_per_group) as f64;

        // local ring all-reduce within the group
        let local = if local_ranks > 1.0 {
            2.0 * (local_ranks - 1.0) * (self.alpha_local_s + bytes / local_ranks / self.beta_local)
        } else {
            0.0
        };
        // leader ring across groups on the full payload
        let global = if n_groups > 1.0 {
            2.0 * (n_groups - 1.0) * (self.alpha_global_s + bytes / n_groups / self.beta_global)
        } else {
            0.0
        };
        // local broadcast of the result (one full-payload hop down a
        // local tree)
        let bcast = if local_ranks > 1.0 {
            (local_ranks.log2().ceil()) * (self.alpha_local_s + bytes / self.beta_local / local_ranks.max(1.0))
        } else {
            0.0
        };
        local + global + bcast
    }

    /// A flat [`NetModel`] with effective parameters matched to this
    /// dragonfly at a given scale (for plugging into the engines, which
    /// take the flat model).
    pub fn effective_net_model(&self, n_elems: usize, n_ranks: usize) -> NetModel {
        let t = self.hierarchical_allreduce_time(n_elems, n_ranks);
        // Solve the flat-ring formula for β with the default α:
        //   t = 2(N−1)(α + b/N/β)  ⇒  β = b/N / (t/(2(N−1)) − α)
        let alpha = self.alpha_local_s;
        let n = n_ranks as f64;
        let bytes = n_elems as f64 * 4.0;
        let per_step = (t / (2.0 * (n - 1.0).max(1.0)) - alpha).max(1e-12);
        NetModel {
            alpha_s: alpha,
            beta_bytes_per_s: bytes / n / per_step,
            algo: AllReduceAlgo::Ring,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_group_is_pure_local_ring() {
        let d = Dragonfly { groups: 1, nodes_per_group: 8, ..Dragonfly::default() };
        let t = d.hierarchical_allreduce_time(1_000_000, 8);
        let local_ring =
            2.0 * 7.0 * (d.alpha_local_s + 4e6 / 8.0 / d.beta_local);
        // plus the local broadcast term
        assert!(t >= local_ring);
        assert!(t < local_ring * 1.5);
    }

    #[test]
    fn cross_group_costs_more_than_local() {
        let d = Dragonfly::default();
        let within = d.hierarchical_allreduce_time(1_000_000, d.nodes_per_group);
        let across = d.hierarchical_allreduce_time(1_000_000, d.n_nodes());
        assert!(across > within, "{across} vs {within}");
    }

    #[test]
    fn monotone_in_payload_and_ranks() {
        let d = Dragonfly::default();
        assert!(
            d.hierarchical_allreduce_time(2_000_000, 64)
                > d.hierarchical_allreduce_time(1_000_000, 64)
        );
        assert!(
            d.hierarchical_allreduce_time(1_000_000, 128)
                > d.hierarchical_allreduce_time(1_000_000, 16)
        );
    }

    #[test]
    fn for_nodes_covers_request() {
        let d = Dragonfly::for_nodes(100);
        assert!(d.n_nodes() >= 100);
    }

    #[test]
    fn effective_model_matches_hierarchical_time() {
        let d = Dragonfly::default();
        let (elems, ranks) = (1_000_000, 64);
        let t_hier = d.hierarchical_allreduce_time(elems, ranks);
        let net = d.effective_net_model(elems, ranks);
        let t_flat = net.allreduce_time(elems, ranks);
        assert!((t_flat - t_hier).abs() / t_hier < 0.05, "{t_flat} vs {t_hier}");
    }

    #[test]
    fn single_rank_free() {
        assert_eq!(Dragonfly::default().hierarchical_allreduce_time(1000, 1), 0.0);
    }
}
