//! Additional collectives over the rendezvous substrate: broadcast,
//! all-gather, reduce-scatter and all-reduce-min/max — the full set a
//! production data-parallel runtime needs (weight sync at start-up,
//! metric aggregation, early-stop votes).
//!
//! All are built on the same round-matched rendezvous as
//! [`super::Comm::iallreduce`], so ordering and determinism guarantees
//! carry over; timing uses the matching [`super::NetModel`] entries.

use std::sync::Arc;

use super::Comm;

impl Comm {
    /// Broadcast `data` from `root` to all ranks. Non-roots pass their
    /// buffer's length in `data` (contents ignored). Returns the root's
    /// payload and this rank's completion time.
    pub fn broadcast(&mut self, data: &[f32], root: usize, now: f64) -> (Arc<Vec<f32>>, f64) {
        // Implemented as an all-reduce where non-roots contribute zeros;
        // cost adjusted to a log-tree broadcast.
        let contribution: Vec<f32> = if self.rank() == root {
            data.to_vec()
        } else {
            vec![0.0; data.len()]
        };
        let (sum, t) = self.allreduce(&contribution, now);
        let n = self.n_ranks();
        let net = self.net_model();
        let t_adj = t - net.allreduce_time(data.len(), n) + net.bcast_time(data.len(), n);
        (sum, t_adj.max(now))
    }

    /// All-gather: every rank contributes `data`; all receive the
    /// rank-ordered concatenation.
    pub fn allgather(&mut self, data: &[f32], now: f64) -> (Vec<f32>, f64) {
        let n = self.n_ranks();
        let len = data.len();
        // contribute into a rank-offset slot of a wide zero vector
        let mut wide = vec![0.0f32; len * n];
        wide[self.rank() * len..(self.rank() + 1) * len].copy_from_slice(data);
        let (sum, t) = self.allreduce(&wide, now);
        let net = self.net_model();
        let t_adj = t - net.allreduce_time(len * n, n) + net.allgather_time(len, n);
        (sum.as_ref().clone(), t_adj.max(now))
    }

    /// Reduce-scatter: the sum is computed and rank i receives chunk i
    /// (last chunk may be short).
    pub fn reduce_scatter(&mut self, data: &[f32], now: f64) -> (Vec<f32>, f64) {
        let n = self.n_ranks();
        let len = data.len();
        let per = len.div_ceil(n);
        let (sum, t) = self.allreduce(data, now);
        let start = (self.rank() * per).min(len);
        let end = ((self.rank() + 1) * per).min(len);
        let net = self.net_model();
        let t_adj = t - net.allreduce_time(len, n) + net.reduce_scatter_time(len, n);
        (sum[start..end].to_vec(), t_adj.max(now))
    }

    /// Global minimum of a scalar across ranks (negate+max via sum trick
    /// is wrong for min; use allgather of scalars).
    pub fn allreduce_min(&mut self, v: f32, now: f64) -> (f32, f64) {
        let (all, t) = self.allgather(&[v], now);
        (all.iter().copied().fold(f32::INFINITY, f32::min), t)
    }

    /// Global maximum of a scalar across ranks.
    pub fn allreduce_max(&mut self, v: f32, now: f64) -> (f32, f64) {
        let (all, t) = self.allgather(&[v], now);
        (all.iter().copied().fold(f32::NEG_INFINITY, f32::max), t)
    }
}

impl super::NetModel {
    /// Log-tree broadcast cost.
    pub fn bcast_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            return 0.0;
        }
        (n_ranks as f64).log2().ceil()
            * (self.alpha_s + n_elems as f64 * 4.0 / self.beta_bytes_per_s)
    }

    /// Ring all-gather cost: (N−1) steps of the per-rank payload.
    pub fn allgather_time(&self, n_elems_per_rank: usize, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            return 0.0;
        }
        (n_ranks as f64 - 1.0)
            * (self.alpha_s + n_elems_per_rank as f64 * 4.0 / self.beta_bytes_per_s)
    }

    /// Ring reduce-scatter cost: (N−1) steps of n/N elements.
    pub fn reduce_scatter_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        if n_ranks <= 1 {
            return 0.0;
        }
        let n = n_ranks as f64;
        (n - 1.0) * (self.alpha_s + n_elems as f64 * 4.0 / n / self.beta_bytes_per_s)
    }
}

#[cfg(test)]
mod tests {
    use crate::comm::{Group, NetModel};
    use std::thread;

    fn spawn<R: Send + 'static>(
        n: usize,
        f: impl Fn(crate::comm::Comm) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let group = Group::new(n, NetModel::instant());
        let f = std::sync::Arc::new(f);
        (0..n)
            .map(|r| {
                let c = group.comm(r);
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    #[test]
    fn broadcast_delivers_root_payload() {
        let out = spawn(4, |mut c| {
            let data = if c.rank() == 2 { vec![5.0, -1.0] } else { vec![0.0, 0.0] };
            c.broadcast(&data, 2, 0.0).0.as_ref().clone()
        });
        for o in out {
            assert_eq!(o, vec![5.0, -1.0]);
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let out = spawn(3, |mut c| {
            let data = vec![c.rank() as f32; 2];
            c.allgather(&data, 0.0).0
        });
        for o in out {
            assert_eq!(o, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn reduce_scatter_chunks() {
        let out = spawn(2, |mut c| {
            let data = vec![1.0, 2.0, 3.0, 4.0, 5.0];
            (c.rank(), c.reduce_scatter(&data, 0.0).0)
        });
        for (rank, chunk) in out {
            // sum = [2,4,6,8,10]; per = 3
            if rank == 0 {
                assert_eq!(chunk, vec![2.0, 4.0, 6.0]);
            } else {
                assert_eq!(chunk, vec![8.0, 10.0]);
            }
        }
    }

    #[test]
    fn scalar_min_max() {
        let out = spawn(4, |mut c| {
            let v = c.rank() as f32 * 2.0 - 3.0; // -3,-1,1,3
            let (mn, _) = c.allreduce_min(v, 0.0);
            let (mx, _) = c.allreduce_max(v, 0.0);
            (mn, mx)
        });
        for (mn, mx) in out {
            assert_eq!(mn, -3.0);
            assert_eq!(mx, 3.0);
        }
    }

    #[test]
    fn cost_model_entries() {
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, ..NetModel::default() };
        assert_eq!(net.bcast_time(1000, 1), 0.0);
        assert!(net.bcast_time(1000, 8) > 0.0);
        assert!(net.allgather_time(1000, 8) > net.reduce_scatter_time(1000, 8));
    }
}
