//! Additional collectives over the rendezvous substrate: broadcast,
//! all-gather, reduce-scatter and all-reduce-min/max — the full set a
//! production data-parallel runtime needs (weight sync at start-up,
//! metric aggregation, early-stop votes).
//!
//! These used to be emulated through a full-width all-reduce (each rank
//! contributing an O(n·N) zero-padded vector and relying on a timing
//! adjustment to hide the waste). They are now **native round kinds**
//! on the rendezvous substrate: each rank posts exactly its own O(n)
//! contribution, the round completes with the operation's real
//! semantics (concatenate / deliver-root / sum), and the cost comes
//! straight from the active [`super::CollectiveSchedule`]'s matching
//! entry — no subtract-the-wrong-cost arithmetic.

use std::sync::Arc;

use super::{Comm, RoundKind};

impl Comm {
    /// The secondary collectives below index and chunk by *raw rank id*
    /// over a launch-contiguous world — they predate membership epochs
    /// and are not yet roster-aware (the elastic engines use only the
    /// all-reduce path). Fail loudly instead of mis-slicing if someone
    /// reaches them on a mutated group; the check is negligible next to
    /// the collective's own payload copies, so it runs in release too.
    fn assert_fixed_membership(&self, op: &str) {
        assert!(
            self.members() == (0..self.n_ranks()).collect::<Vec<_>>(),
            "{op} is not membership-epoch aware: it needs the launch-contiguous world \
             (use the all-reduce path on elastic groups)"
        );
    }
    /// Broadcast `data` from `root` to all ranks. Non-roots' `data` is
    /// ignored (pass `&[]`). Returns the root's payload and this rank's
    /// completion time.
    pub fn broadcast(&mut self, data: &[f32], root: usize, now: f64) -> (Arc<Vec<f32>>, f64) {
        self.assert_fixed_membership("broadcast");
        assert!(root < self.n_ranks());
        let contribution: &[f32] = if self.rank() == root { data } else { &[] };
        let algo = self.net_model().algo;
        let (payload, t, _) =
            self.post(contribution, now, RoundKind::Broadcast { root }, algo).wait_timed(now);
        (payload, t)
    }

    /// All-gather: every rank contributes `data` (equal lengths); all
    /// receive the rank-ordered concatenation.
    pub fn allgather(&mut self, data: &[f32], now: f64) -> (Vec<f32>, f64) {
        self.assert_fixed_membership("allgather");
        let algo = self.net_model().algo;
        let (payload, t, _) = self.post(data, now, RoundKind::AllGather, algo).wait_timed(now);
        (payload.as_ref().clone(), t)
    }

    /// Reduce-scatter: the sum is computed and rank i receives chunk i
    /// (last chunk may be short).
    pub fn reduce_scatter(&mut self, data: &[f32], now: f64) -> (Vec<f32>, f64) {
        self.assert_fixed_membership("reduce_scatter");
        let n = self.n_ranks();
        let len = data.len();
        let per = len.div_ceil(n);
        let algo = self.net_model().algo;
        let (sum, t, _) = self.post(data, now, RoundKind::ReduceScatter, algo).wait_timed(now);
        let start = (self.rank() * per).min(len);
        let end = ((self.rank() + 1) * per).min(len);
        (sum[start..end].to_vec(), t)
    }

    /// Global minimum of a scalar across ranks (negate+max via sum trick
    /// is wrong for min; use allgather of scalars).
    pub fn allreduce_min(&mut self, v: f32, now: f64) -> (f32, f64) {
        let (all, t) = self.allgather(&[v], now);
        (all.iter().copied().fold(f32::INFINITY, f32::min), t)
    }

    /// Global maximum of a scalar across ranks.
    pub fn allreduce_max(&mut self, v: f32, now: f64) -> (f32, f64) {
        let (all, t) = self.allgather(&[v], now);
        (all.iter().copied().fold(f32::NEG_INFINITY, f32::max), t)
    }
}

impl super::NetModel {
    /// Broadcast cost on the configured schedule.
    pub fn bcast_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.schedule().bcast_phases(n_elems, n_ranks).total()
    }

    /// All-gather cost on the configured schedule (per-rank payload).
    pub fn allgather_time(&self, n_elems_per_rank: usize, n_ranks: usize) -> f64 {
        self.schedule().allgather_phases(n_elems_per_rank, n_ranks).total()
    }

    /// Reduce-scatter cost on the configured schedule.
    pub fn reduce_scatter_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.schedule().reduce_scatter_phases(n_elems, n_ranks).total()
    }
}

#[cfg(test)]
mod tests {
    use crate::comm::{AllReduceAlgo, Dragonfly, Group, NetModel};
    use std::thread;

    fn spawn_with<R: Send + 'static>(
        n: usize,
        net: NetModel,
        f: impl Fn(crate::comm::Comm) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let group = Group::new(n, net);
        let f = std::sync::Arc::new(f);
        (0..n)
            .map(|r| {
                let c = group.comm(r);
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    fn spawn<R: Send + 'static>(
        n: usize,
        f: impl Fn(crate::comm::Comm) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        spawn_with(n, NetModel::instant(), f)
    }

    #[test]
    fn broadcast_delivers_root_payload() {
        let out = spawn(4, |mut c| {
            let data = if c.rank() == 2 { vec![5.0, -1.0] } else { vec![] };
            c.broadcast(&data, 2, 0.0).0.as_ref().clone()
        });
        for o in out {
            assert_eq!(o, vec![5.0, -1.0]);
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let out = spawn(3, |mut c| {
            let data = vec![c.rank() as f32; 2];
            c.allgather(&data, 0.0).0
        });
        for o in out {
            assert_eq!(o, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn reduce_scatter_chunks() {
        let out = spawn(2, |mut c| {
            let data = vec![1.0, 2.0, 3.0, 4.0, 5.0];
            (c.rank(), c.reduce_scatter(&data, 0.0).0)
        });
        for (rank, chunk) in out {
            // sum = [2,4,6,8,10]; per = 3
            if rank == 0 {
                assert_eq!(chunk, vec![2.0, 4.0, 6.0]);
            } else {
                assert_eq!(chunk, vec![8.0, 10.0]);
            }
        }
    }

    #[test]
    fn scalar_min_max() {
        let out = spawn(4, |mut c| {
            let v = c.rank() as f32 * 2.0 - 3.0; // -3,-1,1,3
            let (mn, _) = c.allreduce_min(v, 0.0);
            let (mx, _) = c.allreduce_max(v, 0.0);
            (mn, mx)
        });
        for (mn, mx) in out {
            assert_eq!(mn, -3.0);
            assert_eq!(mx, 3.0);
        }
    }

    #[test]
    fn timings_come_from_the_matching_schedule_entry() {
        // The honest implementations must charge allgather_time for an
        // allgather of the *per-rank* payload — not an all-reduce of the
        // padded width — and likewise for broadcast.
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring };
        let len = 1000usize;
        let out = spawn_with(4, net, move |mut c| {
            let (_, t_ag) = c.allgather(&vec![1.0; len], 0.0);
            let data: Vec<f32> = if c.rank() == 0 { vec![2.0; len] } else { vec![] };
            let (_, t_bc) = c.broadcast(&data, 0, t_ag);
            (t_ag, t_bc)
        });
        let expect_ag = net.allgather_time(len, 4);
        let expect_bc = expect_ag + net.bcast_time(len, 4);
        for (t_ag, t_bc) in out {
            assert!((t_ag - expect_ag).abs() < 1e-15, "{t_ag} vs {expect_ag}");
            assert!((t_bc - expect_bc).abs() < 1e-15, "{t_bc} vs {expect_bc}");
        }
        // sanity: the padded emulation would have cost the full width
        assert!(net.allgather_time(len, 4) < net.allreduce_time(len * 4, 4));
    }

    #[test]
    fn collectives_work_on_hierarchical_schedule() {
        let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Dragonfly::default() };
        let net = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let out = spawn_with(4, net, |mut c| {
            let (g, t) = c.allgather(&[c.rank() as f32], 0.0);
            (g, t)
        });
        let expect_t = net.allgather_time(1, 4);
        for (g, t) in out {
            assert_eq!(g, vec![0.0, 1.0, 2.0, 3.0]);
            assert!((t - expect_t).abs() < 1e-15);
        }
    }

    #[test]
    fn cost_model_entries() {
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, ..NetModel::default() };
        assert_eq!(net.bcast_time(1000, 1), 0.0);
        assert!(net.bcast_time(1000, 8) > 0.0);
        assert!(net.allgather_time(1000, 8) > net.reduce_scatter_time(1000, 8));
    }
}
