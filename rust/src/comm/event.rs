//! Event-queue simulator core — cohort-folded rounds at fleet scale.
//!
//! The rendezvous substrate ([`super::Group`]) materializes one worker
//! thread per rank, which is exact but tops out near N ≈ 1024. This
//! module is the other end of the trade: a **timing-only** round
//! simulator that folds homogeneous ranks into closed-form cohort
//! aggregates and materializes *only* the ranks an event touches, so
//! the flat-vs-hier and contention crossover tables tabulate at
//! 65k–1M ranks in milliseconds per round.
//!
//! ## The materialize/fold criterion
//!
//! A rank stays **folded** into its cohort exactly when its per-round
//! timing is a closed-form function of the cohort key:
//!
//! * same compute tier (the `hetero` keyed-RNG draw
//!   [`crate::hetero::tier_multiplier`] — a pure `(seed, rank)`
//!   function, so cohort membership never needs per-rank state), and
//! * no pending event (fault/revocation, join, probe, quarantine)
//!   between now and the horizon, and
//! * no diurnal modulation (`diurnal_amplitude == 0`): the diurnal
//!   phase is per-rank, so a cohort's slowest member changes with `t`
//!   and the fold has no closed form — diurnal fleets run fully
//!   materialized.
//!
//! A cohort of `count` ranks at tier `τ` contributes `count` to the
//! round's contributor total and `τ · t_compute` to the straggler max —
//! O(1) per cohort per round. When an event fires for a folded rank,
//! the cohort **splits**: its count drops by one and the rank moves to
//! the materialized arena; after [`REFOLD_QUIET_ROUNDS`] quiet rounds
//! (no further pending events) it folds back.
//!
//! ## Differential contract
//!
//! [`CohortSim::materialize_all`] runs the identical per-round
//! arithmetic with every rank materialized (the dense reference). Both
//! modes take the max over the same set of f64 products and price the
//! same collective, so their [`RoundStat`] traces are **bit-identical**
//! — pinned by the unit suite here and exercised by `benches/scale.rs`.
//!
//! Events apply at round boundaries, ordered by virtual time (ties
//! break by rank then kind), which is exactly the contributor-set
//! delta ordering of the rendezvous substrate: a revocation observed
//! at `t` shrinks the next round's expected contributor set.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use super::NetModel;
use crate::hetero::{diurnal_factor, revocation_time, tier_multiplier, HeteroConfig};

/// Rounds a materialized rank must stay quiet (no events fired or
/// pending) before it folds back into its tier cohort.
pub const REFOLD_QUIET_ROUNDS: u64 = 2;

/// What happened to a rank, in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FleetEventKind {
    /// Spot revocation: the rank leaves the fleet permanently.
    Revoke,
    /// A scripted joiner enters the fleet (rank ids beyond the initial
    /// world).
    Join,
    /// The control plane probes this rank's schedule arm: materialized
    /// for the probe window, timing unchanged.
    Probe,
    /// The straggler quarantine excludes this rank from the collective
    /// while keeping it tracked.
    Quarantine,
}

/// One scripted or derived fleet event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetEvent {
    pub kind: FleetEventKind,
    pub rank: usize,
    /// Virtual time the event fires; it takes effect at the next round
    /// boundary at or after `at_s`.
    pub at_s: f64,
}

/// A fleet-scale timing scenario: `n_ranks` workers running `rounds`
/// synchronous windows of `t_compute_s` compute over an `n_elems`
/// payload, under a hetero profile and scripted events.
#[derive(Debug, Clone)]
pub struct ScaleScenario {
    pub n_ranks: usize,
    pub n_elems: usize,
    pub t_compute_s: f64,
    pub rounds: u64,
    pub net: NetModel,
    pub hetero: HeteroConfig,
    pub seed: u64,
    /// Scripted events (joins, probes, quarantines); spot revocations
    /// are derived from the hetero keyed-RNG streams automatically.
    pub events: Vec<FleetEvent>,
}

impl ScaleScenario {
    /// A homogeneous baseline: no hetero, no events.
    pub fn uniform(n_ranks: usize, n_elems: usize, t_compute_s: f64, net: NetModel) -> Self {
        ScaleScenario {
            n_ranks,
            n_elems,
            t_compute_s,
            rounds: 1,
            net,
            hetero: HeteroConfig::default(),
            seed: 0,
            events: Vec::new(),
        }
    }
}

/// Per-round trace entry. `materialized` is mode-specific diagnostics
/// (the dense reference materializes everyone); the differential
/// contract covers `(round, t_complete, contributors)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundStat {
    pub round: u64,
    /// Shared completion time of the round's collective.
    pub t_complete: f64,
    /// How many ranks contributed.
    pub contributors: usize,
    /// How many ranks were individually materialized this round.
    pub materialized: usize,
}

/// Fold/materialize accounting over a [`CohortSim`]'s lifetime — the
/// evidence that the arena stays **event-bounded**: ranks materialize
/// only when an event touches them, so `arena_max` tracks the event
/// script (plus derived revocations), not the fleet size, and every
/// refold is paid for by a prior split.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FoldStats {
    /// High-water mark of the materialized arena (ranks tracked
    /// individually at once). Dense mode reports the fleet size.
    pub arena_max: usize,
    /// Ranks folded back into their tier cohort after going quiet.
    pub refolds: u64,
    /// Events that actually fired (cursor advanced past them).
    pub events_applied: u64,
    /// Total events in the schedule (scripted + derived revocations).
    pub events_total: u64,
}

/// A materialized rank's state in the arena.
#[derive(Debug, Clone, Copy)]
struct RankState {
    tier: f64,
    quarantined: bool,
    /// Rounds since the last event touched this rank.
    quiet: u64,
}

/// The event-queue core. See the module docs for the fold criterion.
pub struct CohortSim {
    sc: ScaleScenario,
    /// Folded cohorts: tier bits → member count. Keyed by the tier's
    /// bit pattern so iteration order is deterministic.
    cohorts: BTreeMap<u64, usize>,
    /// Individually tracked ranks (the arena).
    materialized: BTreeMap<usize, RankState>,
    /// All events (scripted + derived revocations), sorted by
    /// (time, rank, kind); `cursor` advances as they fire.
    events: Vec<FleetEvent>,
    cursor: usize,
    /// Outstanding events per rank — a folded candidate must be at 0.
    pending: HashMap<usize, u32>,
    /// Revoked ranks: later events targeting them are no-ops.
    dead: BTreeSet<usize>,
    /// Scripted joiners whose Join event has fired (ranks beyond the
    /// initial world enter the population here).
    joined: BTreeSet<usize>,
    /// `materialize_all` reference mode: never fold.
    dense: bool,
    t: f64,
    round: u64,
    stats: FoldStats,
}

impl CohortSim {
    /// The folded simulator (cohorts where the criterion allows).
    pub fn new(scenario: ScaleScenario) -> Self {
        Self::build(scenario, false)
    }

    /// The dense reference: identical arithmetic, every rank
    /// materialized from the start, nothing ever folds.
    pub fn materialize_all(scenario: ScaleScenario) -> Self {
        Self::build(scenario, true)
    }

    fn build(sc: ScaleScenario, dense: bool) -> Self {
        let mut events = sc.events.clone();
        if sc.hetero.enabled {
            for r in 0..sc.n_ranks {
                if let Some(at_s) = revocation_time(&sc.hetero, sc.seed, r) {
                    events.push(FleetEvent { kind: FleetEventKind::Revoke, rank: r, at_s });
                }
            }
        }
        events.sort_by(|a, b| {
            a.at_s
                .total_cmp(&b.at_s)
                .then(a.rank.cmp(&b.rank))
                .then(a.kind.cmp(&b.kind))
        });
        let mut pending: HashMap<usize, u32> = HashMap::new();
        for e in &events {
            *pending.entry(e.rank).or_insert(0) += 1;
        }
        // Diurnal modulation breaks the closed form (per-rank phase):
        // materialize the whole fleet.
        let fold = !dense && !(sc.hetero.enabled && sc.hetero.diurnal_amplitude > 0.0);
        let mut cohorts: BTreeMap<u64, usize> = BTreeMap::new();
        let mut materialized = BTreeMap::new();
        for r in 0..sc.n_ranks {
            let tier = Self::tier_of(&sc, r);
            if fold && pending.get(&r).copied().unwrap_or(0) == 0 {
                *cohorts.entry(tier.to_bits()).or_insert(0) += 1;
            } else {
                materialized.insert(r, RankState { tier, quarantined: false, quiet: 0 });
            }
        }
        let stats = FoldStats {
            arena_max: materialized.len(),
            events_total: events.len() as u64,
            ..FoldStats::default()
        };
        CohortSim {
            sc,
            cohorts,
            materialized,
            events,
            cursor: 0,
            pending,
            dead: BTreeSet::new(),
            joined: BTreeSet::new(),
            dense,
            t: 0.0,
            round: 0,
            stats,
        }
    }

    fn tier_of(sc: &ScaleScenario, rank: usize) -> f64 {
        if sc.hetero.enabled {
            tier_multiplier(&sc.hetero, sc.seed, rank)
        } else {
            1.0
        }
    }

    /// Folded cohort count (diagnostics; 0 in dense mode once events
    /// have materialized everyone they touch).
    pub fn n_cohorts(&self) -> usize {
        self.cohorts.len()
    }

    /// Currently materialized rank count.
    pub fn n_materialized(&self) -> usize {
        self.materialized.len()
    }

    /// Live contributor count for the next round.
    pub fn n_live(&self) -> usize {
        let folded: usize = self.cohorts.values().sum();
        folded + self.materialized.values().filter(|s| !s.quarantined).count()
    }

    /// Pull `rank` out of its cohort into the arena. No-op if it is
    /// already materialized, already revoked, or not in the population
    /// (a scripted event targeting a never-joined rank). Splitting
    /// recomputes the tier from the pure keyed-RNG draw — folded ranks
    /// carry no per-rank state.
    /// Is `rank` currently in the fleet (folded or materialized)?
    fn in_population(&self, rank: usize) -> bool {
        !self.dead.contains(&rank)
            && (rank < self.sc.n_ranks || self.joined.contains(&rank))
    }

    fn split(&mut self, rank: usize) {
        if !self.in_population(rank) || self.materialized.contains_key(&rank) {
            return;
        }
        let tier = Self::tier_of(&self.sc, rank);
        let key = tier.to_bits();
        let n = self.cohorts.get_mut(&key).expect("folded rank's cohort exists");
        *n -= 1;
        if *n == 0 {
            self.cohorts.remove(&key);
        }
        self.materialized.insert(rank, RankState { tier, quarantined: false, quiet: 0 });
    }

    /// Apply every event that fired at or before `now`.
    fn apply_events(&mut self, now: f64) {
        while self.cursor < self.events.len() && self.events[self.cursor].at_s <= now {
            let e = self.events[self.cursor];
            self.cursor += 1;
            self.stats.events_applied += 1;
            if let Some(p) = self.pending.get_mut(&e.rank) {
                *p -= 1;
            }
            match e.kind {
                FleetEventKind::Revoke => {
                    self.split(e.rank);
                    self.materialized.remove(&e.rank);
                    self.dead.insert(e.rank);
                }
                FleetEventKind::Join => {
                    if !self.in_population(e.rank) {
                        let tier = Self::tier_of(&self.sc, e.rank);
                        self.joined.insert(e.rank);
                        self.materialized
                            .insert(e.rank, RankState { tier, quarantined: false, quiet: 0 });
                    }
                }
                FleetEventKind::Probe => {
                    self.split(e.rank);
                    if let Some(s) = self.materialized.get_mut(&e.rank) {
                        s.quiet = 0;
                    }
                }
                FleetEventKind::Quarantine => {
                    self.split(e.rank);
                    if let Some(s) = self.materialized.get_mut(&e.rank) {
                        s.quarantined = true;
                        s.quiet = 0;
                    }
                }
            }
        }
    }

    /// Fold quiet, event-free, non-quarantined ranks back into their
    /// tier cohorts.
    fn refold(&mut self) {
        if self.dense || (self.sc.hetero.enabled && self.sc.hetero.diurnal_amplitude > 0.0) {
            return;
        }
        let back: Vec<usize> = self
            .materialized
            .iter()
            .filter(|(r, s)| {
                !s.quarantined
                    && s.quiet >= REFOLD_QUIET_ROUNDS
                    && self.pending.get(r).copied().unwrap_or(0) == 0
            })
            .map(|(r, _)| *r)
            .collect();
        for r in back {
            let s = self.materialized.remove(&r).expect("listed above");
            *self.cohorts.entry(s.tier.to_bits()).or_insert(0) += 1;
            self.stats.refolds += 1;
        }
    }

    /// Advance one round: apply due events, take the straggler max over
    /// cohorts and materialized ranks, price the collective over the
    /// live contributor set, refold.
    pub fn step(&mut self) -> RoundStat {
        self.apply_events(self.t);
        self.stats.arena_max = self.stats.arena_max.max(self.materialized.len());
        let t0 = self.t;
        let diurnal = self.sc.hetero.enabled && self.sc.hetero.diurnal_amplitude > 0.0;
        let mut t_post: f64 = t0;
        for key in self.cohorts.keys() {
            let tier = f64::from_bits(*key);
            t_post = t_post.max(t0 + tier * self.sc.t_compute_s);
        }
        for (r, s) in &self.materialized {
            if s.quarantined {
                continue;
            }
            let factor = if diurnal {
                diurnal_factor(&self.sc.hetero, self.sc.seed, *r, t0)
            } else {
                1.0
            };
            t_post = t_post.max(t0 + s.tier * self.sc.t_compute_s * factor);
        }
        let contributors = self.n_live();
        let t_complete =
            t_post + self.sc.net.allreduce_time(self.sc.n_elems, contributors.max(1));
        let stat = RoundStat {
            round: self.round,
            t_complete,
            contributors,
            materialized: self.materialized.len(),
        };
        self.t = t_complete;
        self.round += 1;
        for s in self.materialized.values_mut() {
            s.quiet += 1;
        }
        self.refold();
        stat
    }

    /// Run the scenario's configured round count, returning the trace.
    pub fn run(&mut self) -> Vec<RoundStat> {
        (0..self.sc.rounds).map(|_| self.step()).collect()
    }

    /// Lifetime fold/materialize accounting — see [`FoldStats`].
    pub fn stats(&self) -> FoldStats {
        self.stats
    }

    /// Export the fold accounting into an obs metric registry under the
    /// `sim.cohort.*` namespace (counters; `arena_max` is a high-water
    /// mark across every sim that exports into the same registry).
    pub fn export_obs(&self, m: &crate::obs::Metrics) {
        m.counter_max("sim.cohort.arena_max", self.stats.arena_max as u64);
        m.inc("sim.cohort.refolds", self.stats.refolds);
        m.inc("sim.cohort.events_applied", self.stats.events_applied);
        m.inc("sim.cohort.events_total", self.stats.events_total);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::AllReduceAlgo;

    fn net() -> NetModel {
        NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: AllReduceAlgo::Ring }
    }

    fn hetero_tiers() -> HeteroConfig {
        HeteroConfig {
            enabled: true,
            tiers: vec![1.0, 1.3, 2.0],
            ..HeteroConfig::default()
        }
    }

    fn scripted(kind: FleetEventKind, rank: usize, at_s: f64) -> FleetEvent {
        FleetEvent { kind, rank, at_s }
    }

    /// The differential contract: folded and dense traces are
    /// bit-identical over the full event mix.
    #[test]
    fn folded_trace_is_bit_identical_to_dense() {
        let mut sc = ScaleScenario::uniform(64, 10_000, 1e-3, net());
        sc.rounds = 12;
        sc.hetero = HeteroConfig {
            spot_fraction: 0.2,
            spot_mtbf_s: 0.05,
            ..hetero_tiers()
        };
        sc.seed = 7;
        sc.events = vec![
            scripted(FleetEventKind::Join, 64, 0.004),
            scripted(FleetEventKind::Probe, 3, 0.002),
            scripted(FleetEventKind::Quarantine, 5, 0.006),
        ];
        let folded = CohortSim::new(sc.clone()).run();
        let dense = CohortSim::materialize_all(sc).run();
        assert_eq!(folded.len(), dense.len());
        for (f, d) in folded.iter().zip(&dense) {
            assert_eq!(f.round, d.round);
            assert_eq!(f.contributors, d.contributors, "round {}", f.round);
            assert_eq!(
                f.t_complete.to_bits(),
                d.t_complete.to_bits(),
                "round {} diverged: {} vs {}",
                f.round,
                f.t_complete,
                d.t_complete
            );
        }
    }

    #[test]
    fn homogeneous_fleet_is_one_cohort() {
        let mut sim = CohortSim::new(ScaleScenario::uniform(1_000_000, 1000, 1e-3, net()));
        assert_eq!(sim.n_cohorts(), 1);
        assert_eq!(sim.n_materialized(), 0);
        let stat = sim.step();
        assert_eq!(stat.contributors, 1_000_000);
        let expect = 1e-3 + net().allreduce_time(1000, 1_000_000);
        assert!((stat.t_complete - expect).abs() < 1e-12);
    }

    #[test]
    fn tiered_fleet_folds_to_the_tier_menu() {
        let mut sc = ScaleScenario::uniform(10_000, 1000, 1e-3, net());
        sc.hetero = hetero_tiers();
        let sim = CohortSim::new(sc);
        assert!(sim.n_cohorts() <= 3, "cohorts = tier menu, got {}", sim.n_cohorts());
        assert_eq!(sim.n_materialized(), 0);
    }

    #[test]
    fn revoke_splits_the_cohort_and_shrinks_the_fleet() {
        let mut sc = ScaleScenario::uniform(100, 1000, 1e-3, net());
        sc.rounds = 3;
        sc.events = vec![scripted(FleetEventKind::Revoke, 17, 0.0)];
        let mut sim = CohortSim::new(sc);
        // the pending event keeps rank 17 materialized from birth
        assert_eq!(sim.n_materialized(), 1);
        let s0 = sim.step();
        assert_eq!(s0.contributors, 99, "revocation at t=0 fires before round 0");
        assert_eq!(sim.n_materialized(), 0, "revoked rank leaves the arena");
        assert_eq!(sim.n_live(), 99);
    }

    #[test]
    fn join_materializes_then_refolds_after_quiet_rounds() {
        let mut sc = ScaleScenario::uniform(10, 1000, 1e-3, net());
        sc.rounds = 8;
        sc.events = vec![scripted(FleetEventKind::Join, 10, 0.0005)];
        let mut sim = CohortSim::new(sc);
        let s0 = sim.step();
        assert_eq!(s0.contributors, 10, "join not yet due");
        let s1 = sim.step();
        assert_eq!(s1.contributors, 11, "joiner admitted at the boundary");
        assert_eq!(s1.materialized, 1);
        sim.step();
        let s3 = sim.step();
        assert_eq!(s3.materialized, 0, "quiet joiner refolds into its cohort");
        assert_eq!(s3.contributors, 11);
    }

    #[test]
    fn probe_materializes_without_changing_timing() {
        let mut plain = ScaleScenario::uniform(50, 1000, 1e-3, net());
        plain.rounds = 4;
        let mut probed = plain.clone();
        probed.events = vec![scripted(FleetEventKind::Probe, 9, 0.0005)];
        let a = CohortSim::new(plain).run();
        let b = CohortSim::new(probed).run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.t_complete.to_bits(), y.t_complete.to_bits());
            assert_eq!(x.contributors, y.contributors);
        }
        assert!(b[1].materialized >= 1, "probe splits the rank out");
    }

    #[test]
    fn quarantine_excludes_the_rank_but_keeps_it_tracked() {
        let mut sc = ScaleScenario::uniform(20, 1000, 1e-3, net());
        sc.rounds = 4;
        sc.events = vec![scripted(FleetEventKind::Quarantine, 4, 0.0005)];
        let mut sim = CohortSim::new(sc);
        let s0 = sim.step();
        assert_eq!(s0.contributors, 20);
        let s1 = sim.step();
        assert_eq!(s1.contributors, 19, "quarantined rank leaves the collective");
        assert_eq!(s1.materialized, 1, "but stays in the arena");
        let s2 = sim.step();
        assert_eq!(s2.materialized, 1, "quarantine never refolds");
    }

    #[test]
    fn diurnal_fleets_run_fully_materialized() {
        let mut sc = ScaleScenario::uniform(32, 1000, 1e-3, net());
        sc.rounds = 3;
        sc.hetero = HeteroConfig {
            enabled: true,
            diurnal_amplitude: 0.25,
            diurnal_period_s: 10.0,
            ..HeteroConfig::default()
        };
        let folded = CohortSim::new(sc.clone());
        assert_eq!(folded.n_cohorts(), 0, "no closed form under diurnal");
        assert_eq!(folded.n_materialized(), 32);
        // and the trace still matches the dense reference exactly
        let a = CohortSim::new(sc.clone()).run();
        let b = CohortSim::materialize_all(sc).run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.t_complete.to_bits(), y.t_complete.to_bits());
        }
    }

    #[test]
    fn fold_stats_are_event_bounded_and_export_to_obs() {
        let mut sc = ScaleScenario::uniform(10_000, 1000, 1e-3, net());
        sc.rounds = 10;
        sc.hetero = hetero_tiers();
        sc.events = vec![
            scripted(FleetEventKind::Probe, 3, 0.001),
            scripted(FleetEventKind::Join, 10_000, 0.002),
            scripted(FleetEventKind::Revoke, 17, 0.003),
        ];
        let mut sim = CohortSim::new(sc);
        sim.run();
        let st = sim.stats();
        assert_eq!(st.events_total, 3);
        assert_eq!(st.events_applied, 3, "every scripted event fires within 10 rounds");
        // Event-bounded arena: only touched ranks ever materialize.
        assert!(st.arena_max <= st.events_total as usize, "arena {} > events", st.arena_max);
        assert!(st.refolds <= st.events_total, "refolds {} > events", st.refolds);
        assert!(st.refolds >= 1, "the quiet probe/join ranks fold back");
        let m = crate::obs::Metrics::new();
        sim.export_obs(&m);
        assert_eq!(m.counter("sim.cohort.arena_max"), st.arena_max as u64);
        assert_eq!(m.counter("sim.cohort.events_applied"), 3);
    }

    #[test]
    fn million_rank_round_is_cheap() {
        // O(cohorts + materialized) per round: 1M folded ranks step in
        // far under a millisecond each — the property the scale bench's
        // wall-clock ceiling rides on. Constructing the sim is the only
        // O(N) pass.
        let mut sc = ScaleScenario::uniform(1_048_576, 271_690, 0.1, net());
        sc.rounds = 50;
        sc.hetero = hetero_tiers();
        let mut sim = CohortSim::new(sc);
        let stats = sim.run();
        assert_eq!(stats.len(), 50);
        assert!(stats.iter().all(|s| s.contributors == 1_048_576));
        assert!(sim.n_cohorts() <= 3);
    }
}
