//! Wire-level hierarchical (Layered-SGD) all-reduce: the grouped
//! schedule executed over real per-edge channels between worker
//! threads.
//!
//! [`super::ring`] proves the flat ring schedule really computes the
//! sum the rendezvous substrate reports; this module does the same for
//! the [`super::schedule::Hierarchical`] schedule the cost model
//! prices: each dragonfly group runs a ring all-reduce over its
//! members (**local** links), the group leaders run a ring all-reduce
//! across groups (**global** links), and each leader broadcasts the
//! result back to its members (local links). Per-phase message volume
//! is returned so `benches/allreduce.rs` can account local vs global
//! bytes — the split the [`super::PhaseTimes`] model claims.
//!
//! (This file sits inside the CI rustfmt gate — `cargo fmt` clean —
//! alongside the rest of the schedule-aware comm layer.)

use std::sync::mpsc::{channel, Receiver, Sender};

use super::schedule::{PhaseTimes, LEADER_RING_FLOWS};
use super::topology::Dragonfly;

/// Message volume one rank moved, split by link class (f32 elements and
/// message counts — the α and β inputs of the wire-level pricing).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierVolume {
    pub local_elems: usize,
    pub global_elems: usize,
    /// Messages sent on intra-group links.
    pub local_msgs: usize,
    /// Messages sent on inter-group links.
    pub global_msgs: usize,
}

impl HierVolume {
    /// Price this rank's *measured* wire movement on the dragonfly's
    /// links: per-message α plus bytes over β, with the global bytes
    /// riding the **contended** leader-phase link (the identical
    /// [`super::topology::GlobalContention`] pricing the cost model
    /// uses, at [`LEADER_RING_FLOWS`] flows per group). This is the
    /// differential check that modelled and wire-level t_AR agree under
    /// load: a leader's priced global phase equals the model's leader
    /// ring term whenever the chunks divide evenly.
    pub fn priced(&self, d: &Dragonfly) -> PhaseTimes {
        let ll = d.local_link();
        let gl = d.contended_global_link(LEADER_RING_FLOWS);
        PhaseTimes {
            local_s: self.local_msgs as f64 * ll.alpha_s
                + self.local_elems as f64 * 4.0 / ll.beta_bytes_per_s,
            global_s: self.global_msgs as f64 * gl.alpha_s
                + self.global_elems as f64 * 4.0 / gl.beta_bytes_per_s,
        }
    }
}

/// Per-rank endpoint of a hierarchical network.
pub struct HierComm {
    rank: usize,
    n: usize,
    /// Group index and position within the group.
    group: usize,
    group_rank: usize,
    group_len: usize,
    n_groups: usize,
    /// Intra-group ring (absent in singleton groups).
    local_tx: Option<Sender<Vec<f32>>>,
    local_rx: Option<Receiver<Vec<f32>>>,
    /// Leader ring (leaders of multi-group networks only).
    leader_tx: Option<Sender<Vec<f32>>>,
    leader_rx: Option<Receiver<Vec<f32>>>,
    /// Result fan-out: leader → members.
    bcast_tx: Vec<Sender<Vec<f32>>>,
    bcast_rx: Option<Receiver<Vec<f32>>>,
}

/// Build the hierarchical topology for `n` ranks in contiguous groups
/// of `nodes_per_group` (the last group may be short). Rank `g·m` is
/// group `g`'s leader.
pub fn hier_network(n: usize, nodes_per_group: usize) -> Vec<HierComm> {
    assert!(n >= 1);
    let m = nodes_per_group.max(1);
    let n_groups = n.div_ceil(m);

    // Channel slots per rank, filled group by group then taken once.
    let mut local_tx: Vec<Option<Sender<Vec<f32>>>> = (0..n).map(|_| None).collect();
    let mut local_rx: Vec<Option<Receiver<Vec<f32>>>> = (0..n).map(|_| None).collect();
    let mut leader_tx: Vec<Option<Sender<Vec<f32>>>> = (0..n).map(|_| None).collect();
    let mut leader_rx: Vec<Option<Receiver<Vec<f32>>>> = (0..n).map(|_| None).collect();
    let mut bcast_tx: Vec<Vec<Sender<Vec<f32>>>> = (0..n).map(|_| Vec::new()).collect();
    let mut bcast_rx: Vec<Option<Receiver<Vec<f32>>>> = (0..n).map(|_| None).collect();

    for g in 0..n_groups {
        let start = g * m;
        let len = m.min(n - start);
        if len > 1 {
            // member i sends into channel i, read by member (i+1) % len
            let chans: Vec<_> = (0..len).map(|_| channel()).collect();
            let mut rxs: Vec<Option<Receiver<Vec<f32>>>> = Vec::with_capacity(len);
            for (tx, rx) in chans {
                local_tx[start + rxs.len()] = Some(tx);
                rxs.push(Some(rx));
            }
            for i in 0..len {
                local_rx[start + i] = rxs[(i + len - 1) % len].take();
            }
            // leader → member result channels
            for i in 1..len {
                let (tx, rx) = channel();
                bcast_tx[start].push(tx);
                bcast_rx[start + i] = Some(rx);
            }
        }
    }
    if n_groups > 1 {
        let chans: Vec<_> = (0..n_groups).map(|_| channel()).collect();
        let mut rxs: Vec<Option<Receiver<Vec<f32>>>> = Vec::with_capacity(n_groups);
        for (g, (tx, rx)) in chans.into_iter().enumerate() {
            leader_tx[g * m] = Some(tx);
            rxs.push(Some(rx));
        }
        for g in 0..n_groups {
            leader_rx[g * m] = rxs[(g + n_groups - 1) % n_groups].take();
        }
    }

    (0..n)
        .map(|rank| {
            let group = rank / m;
            let start = group * m;
            HierComm {
                rank,
                n,
                group,
                group_rank: rank - start,
                group_len: m.min(n - start),
                n_groups,
                local_tx: local_tx[rank].take(),
                local_rx: local_rx[rank].take(),
                leader_tx: leader_tx[rank].take(),
                leader_rx: leader_rx[rank].take(),
                bcast_tx: std::mem::take(&mut bcast_tx[rank]),
                bcast_rx: bcast_rx[rank].take(),
            }
        })
        .collect()
}

/// One textbook ring all-reduce (reduce-scatter + all-gather) over the
/// given unidirectional ring endpoints; returns (elements, messages)
/// sent.
fn ring_allreduce(
    buf: &mut [f32],
    ring_rank: usize,
    ring_n: usize,
    tx: &Sender<Vec<f32>>,
    rx: &Receiver<Vec<f32>>,
) -> (usize, usize) {
    let n = ring_n;
    if n == 1 {
        return (0, 0);
    }
    let len = buf.len();
    let per = len.div_ceil(n);
    let bounds = |c: usize| ((c * per).min(len), ((c + 1) * per).min(len));
    let mut sent = 0usize;
    let mut msgs = 0usize;

    // Phase 1: reduce-scatter. At step s, rank r sends chunk (r − s)
    // mod n and receives+accumulates chunk (r − s − 1) mod n.
    for s in 0..n - 1 {
        let (a, b) = bounds((ring_rank + n - s) % n);
        tx.send(buf[a..b].to_vec()).expect("ring peer alive");
        sent += b - a;
        msgs += 1;
        let (a, b) = bounds((ring_rank + n - s - 1) % n);
        let incoming = rx.recv().expect("ring peer alive");
        assert_eq!(incoming.len(), b - a, "chunk size mismatch");
        for (dst, src) in buf[a..b].iter_mut().zip(&incoming) {
            *dst += src;
        }
    }

    // Phase 2: all-gather of the reduced chunks.
    for s in 0..n - 1 {
        let (a, b) = bounds((ring_rank + 1 + n - s) % n);
        tx.send(buf[a..b].to_vec()).expect("ring peer alive");
        sent += b - a;
        msgs += 1;
        let (a, b) = bounds((ring_rank + n - s) % n);
        let incoming = rx.recv().expect("ring peer alive");
        assert_eq!(incoming.len(), b - a, "chunk size mismatch");
        buf[a..b].copy_from_slice(&incoming);
    }
    (sent, msgs)
}

impl HierComm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    pub fn group(&self) -> usize {
        self.group
    }

    pub fn is_leader(&self) -> bool {
        self.group_rank == 0
    }

    /// In-place hierarchical all-reduce (sum). All ranks must call with
    /// equal buffer lengths. Three phases: intra-group ring, leader
    /// ring, local result fan-out. Returns this rank's per-link-class
    /// message volume.
    pub fn allreduce(&self, buf: &mut [f32]) -> HierVolume {
        let mut vol = HierVolume::default();
        if self.n == 1 {
            return vol;
        }

        // Phase 1 (local links): ring all-reduce among group members —
        // every member ends with the group sum.
        if self.group_len > 1 {
            let tx = self.local_tx.as_ref().expect("local ring endpoint");
            let rx = self.local_rx.as_ref().expect("local ring endpoint");
            let (elems, msgs) = ring_allreduce(buf, self.group_rank, self.group_len, tx, rx);
            vol.local_elems += elems;
            vol.local_msgs += msgs;
        }
        if self.n_groups == 1 {
            return vol; // the group sum is already the global sum
        }

        // Phase 2 (global links): leaders ring-all-reduce the group sums.
        if self.is_leader() {
            let tx = self.leader_tx.as_ref().expect("leader ring endpoint");
            let rx = self.leader_rx.as_ref().expect("leader ring endpoint");
            let (elems, msgs) = ring_allreduce(buf, self.group, self.n_groups, tx, rx);
            vol.global_elems += elems;
            vol.global_msgs += msgs;
        }

        // Phase 3 (local links): leaders fan the result out.
        if self.is_leader() {
            for tx in &self.bcast_tx {
                tx.send(buf.to_vec()).expect("member alive");
                vol.local_elems += buf.len();
                vol.local_msgs += 1;
            }
        } else {
            let rx = self.bcast_rx.as_ref().expect("bcast endpoint");
            let incoming = rx.recv().expect("leader alive");
            assert_eq!(incoming.len(), buf.len());
            buf.copy_from_slice(&incoming);
        }
        vol
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::thread;

    /// Run a hierarchical all-reduce over seeded random inputs; check
    /// every rank against the serial sum and return (results, volumes).
    fn run_hier(n: usize, m: usize, len: usize, seed: u64) -> Vec<(Vec<f32>, HierVolume)> {
        let comms = hier_network(n, m);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                thread::spawn(move || {
                    let mut rng = Rng::keyed(seed, c.rank() as u64, 0);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf);
                    let local = buf.clone();
                    let vol = c.allreduce(&mut buf);
                    (local, buf, vol)
                })
            })
            .collect();
        let results: Vec<(Vec<f32>, Vec<f32>, HierVolume)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut expect = vec![0.0f32; len];
        for (local, _, _) in &results {
            for (e, x) in expect.iter_mut().zip(local) {
                *e += x;
            }
        }
        results
            .into_iter()
            .map(|(_, reduced, vol)| {
                for (r, e) in reduced.iter().zip(&expect) {
                    assert!((r - e).abs() <= 1e-4 * e.abs().max(1.0), "{r} vs {e}");
                }
                (reduced, vol)
            })
            .collect()
    }

    #[test]
    fn hier_matches_sum_even_groups() {
        run_hier(8, 4, 128, 1);
        run_hier(6, 2, 64, 2);
    }

    #[test]
    fn hier_matches_sum_uneven_and_degenerate_groups() {
        run_hier(7, 3, 61, 3); // groups 3, 3, 1
        run_hier(5, 1, 16, 4); // every rank a leader: pure global ring
        run_hier(8, 8, 33, 5); // single group: pure local ring
        run_hier(3, 5, 4, 6); // group larger than world
    }

    #[test]
    fn hier_all_ranks_agree() {
        let out = run_hier(9, 3, 500, 7);
        for (r, _) in &out[1..] {
            assert_eq!(r, &out[0].0);
        }
    }

    #[test]
    fn hier_single_rank_noop() {
        let comms = hier_network(1, 4);
        let mut buf = vec![1.0, 2.0];
        let vol = comms[0].allreduce(&mut buf);
        assert_eq!(vol, HierVolume::default());
        assert_eq!(buf, vec![1.0, 2.0]);
    }

    #[test]
    fn only_leaders_touch_global_links() {
        let n = 8;
        let m = 4;
        let out = run_hier(n, m, 256, 8);
        for (rank, (_, vol)) in out.iter().enumerate() {
            if rank % m == 0 {
                assert!(vol.global_elems > 0, "leader {rank} moved no global data");
            } else {
                assert_eq!(vol.global_elems, 0, "member {rank} crossed a group");
                assert!(vol.local_elems > 0);
            }
        }
    }

    #[test]
    fn hier_matches_wire_ring() {
        // Differential: grouped data movement and the flat ring must
        // agree on the sum (up to float reassociation).
        let n = 6;
        let len = 333;
        let hier_out = run_hier(n, 3, len, 9);
        let ring_comms = crate::comm::ring::ring_network(n);
        let handles: Vec<_> = ring_comms
            .into_iter()
            .map(|c| {
                thread::spawn(move || {
                    let mut rng = Rng::keyed(9, c.rank() as u64, 0);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf);
                    c.allreduce(&mut buf);
                    buf
                })
            })
            .collect();
        for h in handles {
            let ring_buf = h.join().unwrap();
            for (a, b) in ring_buf.iter().zip(&hier_out[0].0) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn wire_priced_global_phase_matches_model_under_contention() {
        // A leader's priced global phase must equal the cost model's
        // leader-ring term — dedicated AND contended — whenever the
        // ring chunks divide evenly: the wire executor and the
        // schedule model price the same bytes through the same
        // GlobalContention.
        use crate::comm::schedule::{CollectiveSchedule, Hierarchical};
        let (n, m, len) = (8usize, 4usize, 1024usize); // G = 2, len % G == 0
        for taper in [2usize, 1] {
            let d = Dragonfly {
                groups: 2,
                nodes_per_group: m,
                global_taper: taper,
                ..Dragonfly::default()
            };
            let out = run_hier(n, m, len, 11 + taper as u64);
            let model = Hierarchical { topology: d }.allreduce_phases(len, n);
            // ranks 0 and 4 are the two leaders
            for leader in [0usize, 4] {
                let priced = out[leader].1.priced(&d);
                assert!(
                    (priced.global_s - model.global_s).abs() <= 1e-12 * model.global_s.max(1.0),
                    "taper {taper}: wire-priced global {} vs modelled {}",
                    priced.global_s,
                    model.global_s
                );
            }
            // members never touch (or get priced on) global links
            for member in [1usize, 2, 3, 5, 6, 7] {
                assert_eq!(out[member].1.priced(&d).global_s, 0.0);
            }
        }
        // and the contended pricing is strictly slower than dedicated
        let vol = run_hier(n, m, len, 17)[0].1;
        let ded =
            Dragonfly { groups: 2, nodes_per_group: m, global_taper: 2, ..Dragonfly::default() };
        let con = Dragonfly { global_taper: 1, ..ded };
        assert!(vol.priced(&con).global_s > vol.priced(&ded).global_s);
        assert_eq!(vol.priced(&con).local_s, vol.priced(&ded).local_s);
    }

    #[test]
    fn message_counts_match_ring_schedule_shape() {
        // 8 ranks in 2 groups of 4: a member sends 2(m−1) local ring
        // messages; a leader adds 2(G−1) global messages plus m−1
        // fan-out sends.
        let out = run_hier(8, 4, 1024, 12);
        for (rank, (_, vol)) in out.iter().enumerate() {
            if rank % 4 == 0 {
                assert_eq!(vol.global_msgs, 2, "leader {rank}");
                assert_eq!(vol.local_msgs, 2 * 3 + 3, "leader {rank}");
            } else {
                assert_eq!(vol.global_msgs, 0, "member {rank}");
                assert_eq!(vol.local_msgs, 2 * 3, "member {rank}");
            }
        }
    }

    #[test]
    fn local_volume_is_ring_optimal_within_group() {
        // In an 8-rank, m=4 network with a 1024-elem payload, a member's
        // local volume is exactly the in-group ring volume 2(m−1)(len/m).
        let out = run_hier(8, 4, 1024, 10);
        let expect_member = 2 * 3 * (1024 / 4);
        for (rank, (_, vol)) in out.iter().enumerate() {
            if rank % 4 != 0 {
                assert_eq!(vol.local_elems, expect_member, "rank {rank}");
            }
        }
    }
}
