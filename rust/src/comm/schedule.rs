//! Collective schedules as first-class objects.
//!
//! [`NetModel::allreduce_time`](super::NetModel::allreduce_time) used to
//! be an enum-switch over closed-form cost formulas, and the dragonfly
//! topology could only reach the engines by flattening its hierarchical
//! schedule back into an *effective* α-β pair
//! (`Dragonfly::effective_net_model` — lossy, and wrong about where the
//! time goes). This module replaces both with a [`CollectiveSchedule`]
//! trait: every collective the rendezvous substrate completes is costed
//! by a schedule object, and every schedule decomposes its cost into
//! **per-phase times** ([`PhaseTimes`]) — time on intra-group
//! (electrical/local) links vs inter-group (optical/global) links — so
//! the control plane and the metrics export can see *where* t_AR is
//! spent, not just how big it is.
//!
//! Four schedules:
//!
//! * [`Ring`] — 2(N−1) steps of n/N elements; bandwidth-optimal, the
//!   flat baseline. All time is "local" (a flat fabric has one link
//!   class).
//! * [`Tree`] — binary reduce + broadcast, 2·⌈log2 N⌉ full-payload
//!   hops; latency-optimal for tiny payloads.
//! * [`FlatStar`] — serialized gather+scatter through rank 0; the
//!   degenerate PS-like pattern kept for the ablation.
//! * [`Hierarchical`] — the Layered-SGD schedule (Yu & Yoo 2019) over a
//!   [`Dragonfly`]: ring all-reduce inside each group on local links,
//!   a leader ring across groups on global links, then a local
//!   broadcast. Its phase split is what makes the t_AR floor of Eq. 14
//!   actionable: at large N the flat ring pays 2(N−1) α's while the
//!   hierarchical schedule pays 2(m−1) local α's + 2(G−1) global α's.
//!
//! Numeric contract: schedules decide *routing and cost*, never the
//! sum. The rendezvous substrate reduces contributions once, in rank
//! order, so any two schedules are **bit-identical in sum** by
//! construction (asserted by the schedule-equivalence proptests); the
//! wire-level [`super::hier`] executor is the differential check that
//! the grouped data movement really computes the same reduction.
//!
//! Phase-split accounting invariant: `local_s + global_s` **is** the
//! reported total ([`PhaseTimes::total`] never holds anything the
//! phases don't), flat schedules report all time as local (one link
//! class), and the hierarchical global phase is priced on the
//! *contended* per-group optics — [`LEADER_RING_FLOWS`] concurrent
//! flows over [`Dragonfly::global_taper`] links (see
//! [`super::topology::GlobalContention`]) — so a taper of 1 slows the
//! leader phases and shifts the flat-vs-hierarchical crossover right,
//! exactly what `benches/allreduce.rs` tabulates.

use super::topology::Dragonfly;

/// Per-phase decomposition of one collective's modelled time.
///
/// `local_s` is time on intra-group (electrical) links, `global_s` on
/// inter-group (optical) links. Flat schedules have a single link class
/// and report everything as local.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    pub local_s: f64,
    pub global_s: f64,
}

impl PhaseTimes {
    pub fn local(t: f64) -> Self {
        PhaseTimes { local_s: t, global_s: 0.0 }
    }

    pub fn total(&self) -> f64 {
        self.local_s + self.global_s
    }

    pub fn accumulate(&mut self, other: PhaseTimes) {
        self.local_s += other.local_s;
        self.global_s += other.global_s;
    }
}

/// A collective schedule: how the ranks move data, costed per phase.
///
/// Implementations must be pure functions of (payload, rank count) —
/// the rendezvous rounds cost each collective at completion time, and
/// every rank must account the identical number.
pub trait CollectiveSchedule: std::fmt::Debug + Send + Sync {
    fn name(&self) -> &'static str;

    /// All-reduce (sum) of `n_elems` f32 across `n_ranks`.
    fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes;

    /// Broadcast of `n_elems` f32 from one root.
    fn bcast_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes;

    /// All-gather where every rank contributes `n_elems_per_rank` f32.
    fn allgather_phases(&self, n_elems_per_rank: usize, n_ranks: usize) -> PhaseTimes;

    /// Reduce-scatter of `n_elems` f32 (each rank keeps ~n/N).
    fn reduce_scatter_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes;

    fn allreduce_time(&self, n_elems: usize, n_ranks: usize) -> f64 {
        self.allreduce_phases(n_elems, n_ranks).total()
    }
}

/// One α-β link class (latency seconds, bandwidth bytes/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    pub alpha_s: f64,
    pub beta_bytes_per_s: f64,
}

impl Link {
    /// One hop of `bytes` over this link.
    fn hop(&self, bytes: f64) -> f64 {
        self.alpha_s + bytes / self.beta_bytes_per_s
    }
}

fn bytes_of(n_elems: usize) -> f64 {
    n_elems as f64 * 4.0
}

/// Flat ring: reduce-scatter + all-gather, 2(N−1) steps of n/N.
#[derive(Debug, Clone, Copy)]
pub struct Ring(pub Link);

impl CollectiveSchedule for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let n = n_ranks as f64;
        PhaseTimes::local(2.0 * (n - 1.0) * self.0.hop(bytes_of(n_elems) / n))
    }

    fn bcast_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        flat_bcast(self.0, n_elems, n_ranks)
    }

    fn allgather_phases(&self, n_elems_per_rank: usize, n_ranks: usize) -> PhaseTimes {
        flat_allgather(self.0, n_elems_per_rank, n_ranks)
    }

    fn reduce_scatter_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        flat_reduce_scatter(self.0, n_elems, n_ranks)
    }
}

/// Binary-tree reduce + broadcast: 2·⌈log2 N⌉ full-payload hops.
#[derive(Debug, Clone, Copy)]
pub struct Tree(pub Link);

impl CollectiveSchedule for Tree {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let hops = 2.0 * (n_ranks as f64).log2().ceil();
        PhaseTimes::local(hops * self.0.hop(bytes_of(n_elems)))
    }

    fn bcast_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        flat_bcast(self.0, n_elems, n_ranks)
    }

    fn allgather_phases(&self, n_elems_per_rank: usize, n_ranks: usize) -> PhaseTimes {
        flat_allgather(self.0, n_elems_per_rank, n_ranks)
    }

    fn reduce_scatter_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        flat_reduce_scatter(self.0, n_elems, n_ranks)
    }
}

/// Serialized gather+scatter through rank 0 — the many-to-few
/// bottleneck, kept for the centralised-vs-decentralised ablation.
#[derive(Debug, Clone, Copy)]
pub struct FlatStar(pub Link);

impl CollectiveSchedule for FlatStar {
    fn name(&self) -> &'static str {
        "flat"
    }

    fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let n = n_ranks as f64;
        PhaseTimes::local(2.0 * (n - 1.0) * self.0.hop(bytes_of(n_elems)))
    }

    fn bcast_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        flat_bcast(self.0, n_elems, n_ranks)
    }

    fn allgather_phases(&self, n_elems_per_rank: usize, n_ranks: usize) -> PhaseTimes {
        flat_allgather(self.0, n_elems_per_rank, n_ranks)
    }

    fn reduce_scatter_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        flat_reduce_scatter(self.0, n_elems, n_ranks)
    }
}

// Shared flat-fabric formulas for the secondary collectives (all three
// flat schedules route them the same way the substrate always has).
fn flat_bcast(link: Link, n_elems: usize, n_ranks: usize) -> PhaseTimes {
    if n_ranks <= 1 {
        return PhaseTimes::default();
    }
    PhaseTimes::local((n_ranks as f64).log2().ceil() * link.hop(bytes_of(n_elems)))
}

fn flat_allgather(link: Link, n_elems_per_rank: usize, n_ranks: usize) -> PhaseTimes {
    if n_ranks <= 1 {
        return PhaseTimes::default();
    }
    PhaseTimes::local((n_ranks as f64 - 1.0) * link.hop(bytes_of(n_elems_per_rank)))
}

fn flat_reduce_scatter(link: Link, n_elems: usize, n_ranks: usize) -> PhaseTimes {
    if n_ranks <= 1 {
        return PhaseTimes::default();
    }
    let n = n_ranks as f64;
    PhaseTimes::local((n - 1.0) * link.hop(bytes_of(n_elems) / n))
}

/// Concurrent inter-group flows one dragonfly group's global links
/// carry during the hierarchical schedule's leader phases: the leader's
/// egress and ingress are in flight simultaneously at every ring (and
/// widest tree) step. With [`Dragonfly::global_taper`] `>=` this, the
/// leader phases ride dedicated optics; below it they contend.
pub const LEADER_RING_FLOWS: usize = 2;

/// The Layered-SGD hierarchical schedule over a dragonfly: intra-group
/// ring all-reduce (local links) → leader ring across groups (global
/// links) → local broadcast of the result.
///
/// The leader phases are priced on the **contended** global link: each
/// group's [`LEADER_RING_FLOWS`] concurrent flows share its
/// `global_taper` optics (see
/// [`GlobalContention`](super::topology::GlobalContention)), so a
/// tapered fabric honestly slows the global phase instead of pretending
/// the leader ring owns dedicated optics.
#[derive(Debug, Clone, Copy)]
pub struct Hierarchical {
    pub topology: Dragonfly,
}

impl Hierarchical {
    fn local_link(&self) -> Link {
        self.topology.local_link()
    }

    /// The per-flow global link during the leader phases — contended by
    /// the [`LEADER_RING_FLOWS`] flows every group keeps in flight.
    fn global_link(&self) -> Link {
        self.topology.contended_global_link(LEADER_RING_FLOWS)
    }

    /// (ranks per group, groups spanned) at a given scale.
    fn shape(&self, n_ranks: usize) -> (f64, f64) {
        let m = self.topology.nodes_per_group.min(n_ranks) as f64;
        let g = n_ranks.div_ceil(self.topology.nodes_per_group) as f64;
        (m, g)
    }
}

impl CollectiveSchedule for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn allreduce_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let bytes = bytes_of(n_elems);
        let (m, g) = self.shape(n_ranks);
        let (ll, gl) = (self.local_link(), self.global_link());

        // ring all-reduce within each group, on local links
        let local_ring = if m > 1.0 {
            2.0 * (m - 1.0) * ll.hop(bytes / m)
        } else {
            0.0
        };
        // leader ring across groups, on global links
        let leader_ring = if g > 1.0 {
            2.0 * (g - 1.0) * gl.hop(bytes / g)
        } else {
            0.0
        };
        // local broadcast of the result down a tree
        let bcast = if m > 1.0 {
            m.log2().ceil() * ll.hop(bytes / m.max(1.0))
        } else {
            0.0
        };
        PhaseTimes { local_s: local_ring + bcast, global_s: leader_ring }
    }

    fn bcast_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let bytes = bytes_of(n_elems);
        let (m, g) = self.shape(n_ranks);
        // leader chain first (global tree), then each leader fans out
        // down its local tree.
        let global = if g > 1.0 {
            g.log2().ceil() * self.global_link().hop(bytes)
        } else {
            0.0
        };
        let local = if m > 1.0 {
            m.log2().ceil() * self.local_link().hop(bytes)
        } else {
            0.0
        };
        PhaseTimes { local_s: local, global_s: global }
    }

    fn allgather_phases(&self, n_elems_per_rank: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let per = bytes_of(n_elems_per_rank);
        let (m, g) = self.shape(n_ranks);
        // assemble the group block locally, ring the blocks across
        // leaders, then push the remote blocks down the local tree.
        let local_gather = if m > 1.0 {
            (m - 1.0) * self.local_link().hop(per)
        } else {
            0.0
        };
        let leader_ring = if g > 1.0 {
            (g - 1.0) * self.global_link().hop(per * m)
        } else {
            0.0
        };
        let local_fanout = if m > 1.0 && g > 1.0 {
            m.log2().ceil() * self.local_link().hop(per * m * (g - 1.0))
        } else {
            0.0
        };
        PhaseTimes { local_s: local_gather + local_fanout, global_s: leader_ring }
    }

    fn reduce_scatter_phases(&self, n_elems: usize, n_ranks: usize) -> PhaseTimes {
        if n_ranks <= 1 {
            return PhaseTimes::default();
        }
        let bytes = bytes_of(n_elems);
        let (m, g) = self.shape(n_ranks);
        let local = if m > 1.0 {
            (m - 1.0) * self.local_link().hop(bytes / m)
        } else {
            0.0
        };
        let global = if g > 1.0 {
            (g - 1.0) * self.global_link().hop(bytes / g)
        } else {
            0.0
        };
        PhaseTimes { local_s: local, global_s: global }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Link {
        Link { alpha_s: 1e-6, beta_bytes_per_s: 1e9 }
    }

    #[test]
    fn ring_matches_closed_form() {
        let s = Ring(link());
        // N=8, 1M f32: 2*7*(1e-6 + 4e6/8/1e9)
        let t = s.allreduce_time(1_000_000, 8);
        assert!((t - (14e-6 + 7.0e-3)).abs() < 1e-9);
        assert_eq!(s.allreduce_time(1_000_000, 1), 0.0);
        // flat schedules report no global time
        assert_eq!(s.allreduce_phases(1_000_000, 8).global_s, 0.0);
    }

    #[test]
    fn schedule_ordering_small_vs_large_payload() {
        let (ring, tree, star) = (Ring(link()), Tree(link()), FlatStar(link()));
        // flat star is slower than ring for large payloads
        assert!(star.allreduce_time(1_000_000, 8) > ring.allreduce_time(1_000_000, 8));
        // tree beats ring on latency for tiny payloads at large N
        assert!(tree.allreduce_time(1, 64) < ring.allreduce_time(1, 64));
    }

    #[test]
    fn hierarchical_beats_ring_at_scale_on_default_dragonfly() {
        // The acceptance crossover: at the ResNet-20 payload, the
        // hierarchical schedule must beat the flat ring for N ≥ 256.
        let elems = 271_690;
        for n in [256usize, 512, 1024] {
            let hier = Hierarchical { topology: Dragonfly::for_nodes(n) };
            let ring = Ring(Link { alpha_s: 1.5e-6, beta_bytes_per_s: 10e9 });
            assert!(
                hier.allreduce_time(elems, n) < ring.allreduce_time(elems, n),
                "hier not faster at N={n}"
            );
        }
    }

    #[test]
    fn hierarchical_phases_split_local_and_global() {
        let h = Hierarchical { topology: Dragonfly::default() };
        let p = h.allreduce_phases(1_000_000, 128);
        assert!(p.local_s > 0.0 && p.global_s > 0.0);
        assert!((p.total() - (p.local_s + p.global_s)).abs() < 1e-18);
        // a single group never touches global links
        let single = Hierarchical {
            topology: Dragonfly { groups: 1, nodes_per_group: 16, ..Dragonfly::default() },
        };
        assert_eq!(single.allreduce_phases(1_000_000, 16).global_s, 0.0);
    }

    #[test]
    fn contended_taper_slows_only_the_global_phase() {
        let ded_topo = Dragonfly { global_taper: 2, ..Dragonfly::default() };
        let con_topo = Dragonfly { global_taper: 1, ..Dragonfly::default() };
        let dedicated = Hierarchical { topology: ded_topo };
        let contended = Hierarchical { topology: con_topo };
        let (elems, n) = (1_000_000, 128);
        let pd = dedicated.allreduce_phases(elems, n);
        let pc = contended.allreduce_phases(elems, n);
        assert_eq!(pc.local_s, pd.local_s, "contention must not touch local links");
        assert!(pc.global_s > pd.global_s, "taper 1 must slow the leader ring");
        // α terms are untouched: the slowdown is exactly the extra
        // bandwidth time, β halved on the global payload.
        let gl = dedicated.topology.global_link();
        let g = n.div_ceil(dedicated.topology.nodes_per_group) as f64;
        let extra = 2.0 * (g - 1.0) * (elems as f64 * 4.0 / g) / gl.beta_bytes_per_s;
        assert!(
            (pc.global_s - pd.global_s - extra).abs() < 1e-12 * pd.global_s.max(1.0),
            "slowdown must be pure bandwidth: got {} want {}",
            pc.global_s - pd.global_s,
            extra
        );
        // the secondary collectives contend the same way
        assert!(
            contended.allgather_phases(1000, n).global_s
                > dedicated.allgather_phases(1000, n).global_s
        );
        assert!(
            contended.reduce_scatter_phases(elems, n).global_s
                > dedicated.reduce_scatter_phases(elems, n).global_s
        );
        assert!(
            contended.bcast_phases(elems, n).global_s
                > dedicated.bcast_phases(elems, n).global_s
        );
    }

    #[test]
    fn taper_at_or_above_leader_flows_is_dedicated() {
        // Anything >= LEADER_RING_FLOWS prices identically — the
        // equality anchor that keeps the default model bit-stable.
        let at_topo = Dragonfly { global_taper: LEADER_RING_FLOWS, ..Dragonfly::default() };
        let above_topo = Dragonfly { global_taper: 8, ..Dragonfly::default() };
        let at = Hierarchical { topology: at_topo };
        let above = Hierarchical { topology: above_topo };
        let pa = at.allreduce_phases(271_690, 256);
        let pb = above.allreduce_phases(271_690, 256);
        assert_eq!(pa, pb);
    }

    #[test]
    fn secondary_collectives_are_finite_and_single_rank_free() {
        let h = Hierarchical { topology: Dragonfly::default() };
        for n in [1usize, 2, 32, 200] {
            for p in [
                h.bcast_phases(1000, n),
                h.allgather_phases(1000, n),
                h.reduce_scatter_phases(1000, n),
            ] {
                assert!(p.total().is_finite());
                if n == 1 {
                    assert_eq!(p.total(), 0.0);
                }
            }
        }
    }
}
