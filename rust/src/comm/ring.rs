//! Wire-level ring all-reduce: reduce-scatter + all-gather over real
//! per-edge channels between worker threads.
//!
//! The rendezvous collectives in [`super`] give MPI *semantics* with
//! modelled timing; this module implements the actual decentralized
//! schedule (the one Cray-mpich runs for large payloads, and the one the
//! [`super::NetModel::allreduce_time`] Ring formula costs): each of the
//! N ranks exchanges 2(N−1) chunk messages with its neighbours, never
//! holding more than `ceil(n/N)` extra elements. Used by
//! `benches/allreduce.rs` and as a differential check on the rendezvous
//! path.

use std::sync::mpsc::{channel, Receiver, Sender};

/// Per-rank endpoint of a ring network (unidirectional: send to
/// `rank+1`, receive from `rank−1`).
pub struct RingComm {
    rank: usize,
    n: usize,
    to_next: Sender<Vec<f32>>,
    from_prev: Receiver<Vec<f32>>,
}

/// Build the ring topology for `n` ranks.
pub fn ring_network(n: usize) -> Vec<RingComm> {
    assert!(n >= 1);
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    // rank i sends into channel i (read by rank i+1).
    let mut receivers: Vec<Option<Receiver<Vec<f32>>>> =
        receivers.into_iter().map(Some).collect();
    (0..n)
        .map(|rank| RingComm {
            rank,
            n,
            to_next: senders[rank].clone(),
            from_prev: receivers[(rank + n - 1) % n].take().expect("each endpoint taken once"),
        })
        .collect()
}

impl RingComm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// Chunk boundaries: chunk `c` covers `[start, end)` of the buffer.
    fn chunk_bounds(&self, c: usize, len: usize) -> (usize, usize) {
        let per = len.div_ceil(self.n);
        let start = (c * per).min(len);
        let end = ((c + 1) * per).min(len);
        (start, end)
    }

    /// In-place ring all-reduce (sum). All ranks must call with equal
    /// buffer lengths. 2(N−1) steps; message count and sizes match the
    /// textbook schedule exactly (asserted in tests).
    ///
    /// Returns the number of payload f32 sent by this rank (for the
    /// bench's bandwidth accounting).
    pub fn allreduce(&self, buf: &mut [f32]) -> usize {
        let n = self.n;
        if n == 1 {
            return 0;
        }
        let len = buf.len();
        let mut sent = 0usize;

        // Phase 1: reduce-scatter. At step s (0..n-1), rank r sends
        // chunk (r - s) mod n and receives+accumulates chunk
        // (r - s - 1) mod n.
        for s in 0..n - 1 {
            let send_c = (self.rank + n - s) % n;
            let (a, b) = self.chunk_bounds(send_c, len);
            self.to_next.send(buf[a..b].to_vec()).expect("ring peer alive");
            sent += b - a;
            let recv_c = (self.rank + n - s - 1) % n;
            let (a, b) = self.chunk_bounds(recv_c, len);
            let incoming = self.from_prev.recv().expect("ring peer alive");
            assert_eq!(incoming.len(), b - a, "chunk size mismatch");
            for (dst, src) in buf[a..b].iter_mut().zip(&incoming) {
                *dst += src;
            }
        }

        // Phase 2: all-gather. Rank r now owns the fully-reduced chunk
        // (r + 1) mod n; circulate the reduced chunks.
        for s in 0..n - 1 {
            let send_c = (self.rank + 1 + n - s) % n;
            let (a, b) = self.chunk_bounds(send_c, len);
            self.to_next.send(buf[a..b].to_vec()).expect("ring peer alive");
            sent += b - a;
            let recv_c = (self.rank + n - s) % n;
            let (a, b) = self.chunk_bounds(recv_c, len);
            let incoming = self.from_prev.recv().expect("ring peer alive");
            assert_eq!(incoming.len(), b - a, "chunk size mismatch");
            buf[a..b].copy_from_slice(&incoming);
        }
        sent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::thread;

    fn run_ring(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let comms = ring_network(n);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                thread::spawn(move || {
                    let mut rng = Rng::keyed(seed, c.rank() as u64, 0);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf);
                    let local = buf.clone();
                    c.allreduce(&mut buf);
                    (local, buf)
                })
            })
            .collect();
        let results: Vec<(Vec<f32>, Vec<f32>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // expected sum
        let mut expect = vec![0.0f32; len];
        for (local, _) in &results {
            for (e, x) in expect.iter_mut().zip(local) {
                *e += x;
            }
        }
        results
            .into_iter()
            .map(|(_, reduced)| {
                for (r, e) in reduced.iter().zip(&expect) {
                    assert!((r - e).abs() <= 1e-4 * e.abs().max(1.0), "{r} vs {e}");
                }
                reduced
            })
            .collect()
    }

    #[test]
    fn ring_matches_sum_small() {
        run_ring(4, 64, 1);
    }

    #[test]
    fn ring_handles_len_not_divisible() {
        run_ring(4, 61, 2); // 61 = 4*16 - 3: last chunk short
        run_ring(3, 1, 3); // fewer elements than ranks
        run_ring(5, 4, 4);
    }

    #[test]
    fn ring_single_rank_noop() {
        let comms = ring_network(1);
        let mut buf = vec![1.0, 2.0];
        let sent = comms[0].allreduce(&mut buf);
        assert_eq!(sent, 0);
        assert_eq!(buf, vec![1.0, 2.0]);
    }

    #[test]
    fn ring_all_ranks_agree() {
        let results = run_ring(6, 1000, 5);
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
    }

    #[test]
    fn ring_message_volume_is_bandwidth_optimal() {
        // Each rank sends 2(N−1)·(n/N) elements (± chunk rounding).
        let n_ranks = 4;
        let len = 1024;
        let comms = ring_network(n_ranks);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                thread::spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    c.allreduce(&mut buf)
                })
            })
            .collect();
        for h in handles {
            let sent = h.join().unwrap();
            let expect = 2 * (n_ranks - 1) * (len / n_ranks);
            assert_eq!(sent, expect);
        }
    }

    #[test]
    fn ring_matches_rendezvous_collective() {
        // Differential test: the wire-level ring and the rendezvous
        // collective must produce identical sums for identical inputs.
        let n = 4;
        let len = 333;
        let ring_out = run_ring(n, len, 7);
        let group = crate::comm::Group::new(n, crate::comm::NetModel::instant());
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let mut c = group.comm(r);
                thread::spawn(move || {
                    let mut rng = Rng::keyed(7, r as u64, 0);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf);
                    c.allreduce(&buf, 0.0).0.as_ref().clone()
                })
            })
            .collect();
        for h in handles {
            let rdv = h.join().unwrap();
            for (a, b) in rdv.iter().zip(&ring_out[0]) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0));
            }
        }
    }
}
