//! Bench (E1): Table I rows, paper-vs-measured.
//!
//! Prints the scaled Table I (DESIGN.md §3/§5): for each row the paper's
//! reported (val acc, speed) next to ours, with the shape checks the
//! reproduction targets (who wins, degradation at the largest batch,
//! throughput scaling with N). A short-steps version of
//! `examples/table1_sweep.rs` that always terminates in bench budgets;
//! uses artifacts when present, else the linear backend.

use std::collections::BTreeMap;

use dcs3gd::algo::{engine_registry, run_experiment, Algo, RunReport};
use dcs3gd::bench_util::write_bench_json;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

struct PaperRow {
    label: &'static str,
    paper_net: &'static str,
    paper_batch: &'static str,
    paper_nodes: usize,
    paper_val_acc: f64,
    paper_speed: f64,
    variant: &'static str,
    local_batch: usize,
    nodes: usize,
}

const ROWS: &[PaperRow] = &[
    PaperRow { label: "r1", paper_net: "ResNet-50", paper_batch: "16k", paper_nodes: 32, paper_val_acc: 77.5, paper_speed: 2078.0, variant: "tiny_cnn_b16", local_batch: 16, nodes: 8 },
    PaperRow { label: "r2", paper_net: "ResNet-50", paper_batch: "32k", paper_nodes: 32, paper_val_acc: 77.4, paper_speed: 2144.0, variant: "tiny_cnn_b32", local_batch: 32, nodes: 8 },
    PaperRow { label: "r3", paper_net: "ResNet-50", paper_batch: "32k", paper_nodes: 64, paper_val_acc: 77.2, paper_speed: 3815.0, variant: "tiny_cnn_b32", local_batch: 32, nodes: 16 },
    PaperRow { label: "r4", paper_net: "ResNet-50", paper_batch: "64k", paper_nodes: 64, paper_val_acc: 75.6, paper_speed: 4245.0, variant: "tiny_cnn_b64", local_batch: 64, nodes: 16 },
    PaperRow { label: "r5", paper_net: "ResNet-50", paper_batch: "128k", paper_nodes: 128, paper_val_acc: 69.7, paper_speed: 8201.0, variant: "tiny_cnn_b64", local_batch: 64, nodes: 32 },
    PaperRow { label: "r6", paper_net: "ResNet-101", paper_batch: "64k", paper_nodes: 64, paper_val_acc: 77.2, paper_speed: 2578.0, variant: "small_cnn_b32", local_batch: 32, nodes: 16 },
    PaperRow { label: "r7", paper_net: "ResNet-152", paper_batch: "32k", paper_nodes: 64, paper_val_acc: 78.7, paper_speed: 1768.0, variant: "resnet20_b32", local_batch: 32, nodes: 16 },
    PaperRow { label: "r8", paper_net: "VGG-16", paper_batch: "16k", paper_nodes: 64, paper_val_acc: 69.2, paper_speed: 1206.0, variant: "mlp_b32", local_batch: 32, nodes: 16 },
];

fn run_row(r: &PaperRow, steps: u64) -> anyhow::Result<RunReport> {
    run_row_with(r, steps, Algo::DcS3gd)
}

fn run_row_with(r: &PaperRow, steps: u64, algo: Algo) -> anyhow::Result<RunReport> {
    let variant = if std::path::Path::new(&format!("artifacts/{}/meta.json", r.variant)).exists() {
        r.variant
    } else {
        "linear"
    };
    let cfg = ExperimentConfig::builder(variant)
        .name(format!("t1b_{}_{}", r.label, algo.name()).leak())
        .algo(algo)
        .nodes(r.nodes)
        .local_batch(r.local_batch)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(256)
        .warmup(0.5, 1.0 / 6.0)
        .data(8192, 1024, 2.5)
        .compute(ComputeModel::default()) // 15 ms/sample ≈ paper node
        .build();
    run_experiment(&cfg)
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 30 } else { 120 };

    println!("# Table I: paper vs measured (scaled testbed — shapes, not absolutes)\n");
    println!(
        "{:<4} {:<11} {:>5} {:>4} | {:>9} {:>11} | {:>6} {:>4} {:>9} {:>11}",
        "row", "paper net", "|B|", "N", "paper val", "paper img/s", "|B|'", "N'", "our val", "our img/s"
    );
    let mut speeds = Vec::new();
    for r in ROWS {
        let rep = run_row(r, steps)?;
        speeds.push((r, rep.sim_throughput, rep.final_val_err));
        println!(
            "{:<4} {:<11} {:>5} {:>4} | {:>8.1}% {:>11.0} | {:>6} {:>4} {:>8.1}% {:>11.0}",
            r.label,
            r.paper_net,
            r.paper_batch,
            r.paper_nodes,
            r.paper_val_acc,
            r.paper_speed,
            r.nodes * r.local_batch,
            r.nodes,
            100.0 * (1.0 - rep.final_val_err),
            rep.sim_throughput
        );
    }

    // Shape assertions, reported not enforced:
    println!("\n# shape checks");
    let speed = |label: &str| speeds.iter().find(|(r, ..)| r.label == label).unwrap().1;
    let err = |label: &str| speeds.iter().find(|(r, ..)| r.label == label).unwrap().2;
    println!(
        "speed scales with N (r2→r3, paper 2144→3815 = 1.78×): ours {:.0}→{:.0} = {:.2}×",
        speed("r2"),
        speed("r3"),
        speed("r3") / speed("r2")
    );
    println!(
        "bigger batch, same N is faster (r3→r4, paper 1.11×): ours {:.2}×",
        speed("r4") / speed("r3")
    );
    println!(
        "largest batch loses accuracy (r4→r5, paper 75.6→69.7): ours {:.1}%→{:.1}%",
        100.0 * (1.0 - err("r4")),
        100.0 * (1.0 - err("r5"))
    );

    // Engine rows: the per-worker-staleness engines (dyn_ssp, sgs) on
    // the r3 geometry next to fixed-k DC-S3GD, so they land in the same
    // BENCH artifact as the paper table.
    let r3 = ROWS.iter().find(|r| r.label == "r3").unwrap();
    println!("\n# engine rows (r3 geometry: N={}, |B|={})", r3.nodes, r3.local_batch);
    println!("{:>8} {:>9} {:>11} {:>12}", "engine", "val", "img/s", "iter_time");
    let mut engine_rows: Vec<Json> = Vec::new();
    for spec in engine_registry().iter().filter(|e| e.bench_row) {
        let algo = spec.algo;
        let rep = run_row_with(r3, steps, algo)?;
        println!(
            "{:>8} {:>8.1}% {:>11.0} {:>11.3e}s",
            algo.name(),
            100.0 * (1.0 - rep.final_val_err),
            rep.sim_throughput,
            rep.mean_iter_time
        );
        let mut row = BTreeMap::new();
        row.insert("engine".to_string(), Json::Str(algo.name().to_string()));
        row.insert("final_val_err".into(), Json::Num(rep.final_val_err as f64));
        row.insert("sim_img_per_s".into(), Json::Num(rep.sim_throughput));
        row.insert("mean_iter_time_s".into(), Json::Num(rep.mean_iter_time));
        engine_rows.push(Json::Obj(row));
    }

    // Machine-readable export: the paper rows plus the engine rows.
    let mut paper_rows: Vec<Json> = Vec::new();
    for (r, img_s, val_err) in &speeds {
        let mut row = BTreeMap::new();
        row.insert("row".to_string(), Json::Str(r.label.to_string()));
        row.insert("nodes".into(), Json::Num(r.nodes as f64));
        row.insert("local_batch".into(), Json::Num(r.local_batch as f64));
        row.insert("paper_val_acc".into(), Json::Num(r.paper_val_acc));
        row.insert("paper_img_per_s".into(), Json::Num(r.paper_speed));
        row.insert("sim_img_per_s".into(), Json::Num(*img_s));
        row.insert("final_val_err".into(), Json::Num(*val_err as f64));
        paper_rows.push(Json::Obj(row));
    }
    let mut section = BTreeMap::new();
    section.insert("rows".to_string(), Json::Arr(paper_rows));
    section.insert("engines".into(), Json::Arr(engine_rows));
    let path = write_bench_json("table1", Json::Obj(section)).expect("bench json");
    println!("\nbench JSON -> {}", path.display());
    Ok(())
}
