//! Bench (E1): Table I rows, paper-vs-measured.
//!
//! Prints the scaled Table I (DESIGN.md §3/§5): for each row the paper's
//! reported (val acc, speed) next to ours, with the shape checks the
//! reproduction targets (who wins, degradation at the largest batch,
//! throughput scaling with N). A short-steps version of
//! `examples/table1_sweep.rs` that always terminates in bench budgets;
//! uses artifacts when present, else the linear backend.

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

struct PaperRow {
    label: &'static str,
    paper_net: &'static str,
    paper_batch: &'static str,
    paper_nodes: usize,
    paper_val_acc: f64,
    paper_speed: f64,
    variant: &'static str,
    local_batch: usize,
    nodes: usize,
}

const ROWS: &[PaperRow] = &[
    PaperRow { label: "r1", paper_net: "ResNet-50", paper_batch: "16k", paper_nodes: 32, paper_val_acc: 77.5, paper_speed: 2078.0, variant: "tiny_cnn_b16", local_batch: 16, nodes: 8 },
    PaperRow { label: "r2", paper_net: "ResNet-50", paper_batch: "32k", paper_nodes: 32, paper_val_acc: 77.4, paper_speed: 2144.0, variant: "tiny_cnn_b32", local_batch: 32, nodes: 8 },
    PaperRow { label: "r3", paper_net: "ResNet-50", paper_batch: "32k", paper_nodes: 64, paper_val_acc: 77.2, paper_speed: 3815.0, variant: "tiny_cnn_b32", local_batch: 32, nodes: 16 },
    PaperRow { label: "r4", paper_net: "ResNet-50", paper_batch: "64k", paper_nodes: 64, paper_val_acc: 75.6, paper_speed: 4245.0, variant: "tiny_cnn_b64", local_batch: 64, nodes: 16 },
    PaperRow { label: "r5", paper_net: "ResNet-50", paper_batch: "128k", paper_nodes: 128, paper_val_acc: 69.7, paper_speed: 8201.0, variant: "tiny_cnn_b64", local_batch: 64, nodes: 32 },
    PaperRow { label: "r6", paper_net: "ResNet-101", paper_batch: "64k", paper_nodes: 64, paper_val_acc: 77.2, paper_speed: 2578.0, variant: "small_cnn_b32", local_batch: 32, nodes: 16 },
    PaperRow { label: "r7", paper_net: "ResNet-152", paper_batch: "32k", paper_nodes: 64, paper_val_acc: 78.7, paper_speed: 1768.0, variant: "resnet20_b32", local_batch: 32, nodes: 16 },
    PaperRow { label: "r8", paper_net: "VGG-16", paper_batch: "16k", paper_nodes: 64, paper_val_acc: 69.2, paper_speed: 1206.0, variant: "mlp_b32", local_batch: 32, nodes: 16 },
];

fn run_row(r: &PaperRow, steps: u64) -> anyhow::Result<RunReport> {
    let variant = if std::path::Path::new(&format!("artifacts/{}/meta.json", r.variant)).exists() {
        r.variant
    } else {
        "linear"
    };
    let cfg = ExperimentConfig::builder(variant)
        .name(format!("t1b_{}", r.label).leak())
        .algo(Algo::DcS3gd)
        .nodes(r.nodes)
        .local_batch(r.local_batch)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(256)
        .warmup(0.5, 1.0 / 6.0)
        .data(8192, 1024, 2.5)
        .compute(ComputeModel::default()) // 15 ms/sample ≈ paper node
        .build();
    run_experiment(&cfg)
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 30 } else { 120 };

    println!("# Table I: paper vs measured (scaled testbed — shapes, not absolutes)\n");
    println!(
        "{:<4} {:<11} {:>5} {:>4} | {:>9} {:>11} | {:>6} {:>4} {:>9} {:>11}",
        "row", "paper net", "|B|", "N", "paper val", "paper img/s", "|B|'", "N'", "our val", "our img/s"
    );
    let mut speeds = Vec::new();
    for r in ROWS {
        let rep = run_row(r, steps)?;
        speeds.push((r, rep.sim_throughput, rep.final_val_err));
        println!(
            "{:<4} {:<11} {:>5} {:>4} | {:>8.1}% {:>11.0} | {:>6} {:>4} {:>8.1}% {:>11.0}",
            r.label,
            r.paper_net,
            r.paper_batch,
            r.paper_nodes,
            r.paper_val_acc,
            r.paper_speed,
            r.nodes * r.local_batch,
            r.nodes,
            100.0 * (1.0 - rep.final_val_err),
            rep.sim_throughput
        );
    }

    // Shape assertions, reported not enforced:
    println!("\n# shape checks");
    let speed = |label: &str| speeds.iter().find(|(r, ..)| r.label == label).unwrap().1;
    let err = |label: &str| speeds.iter().find(|(r, ..)| r.label == label).unwrap().2;
    println!(
        "speed scales with N (r2→r3, paper 2144→3815 = 1.78×): ours {:.0}→{:.0} = {:.2}×",
        speed("r2"),
        speed("r3"),
        speed("r3") / speed("r2")
    );
    println!(
        "bigger batch, same N is faster (r3→r4, paper 1.11×): ours {:.2}×",
        speed("r4") / speed("r3")
    );
    println!(
        "largest batch loses accuracy (r4→r5, paper 75.6→69.7): ours {:.1}%→{:.1}%",
        100.0 * (1.0 - err("r4")),
        100.0 * (1.0 - err("r5"))
    );
    Ok(())
}
