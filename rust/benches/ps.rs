//! Bench: the parameter-server tier — pull latency under hot-shard
//! traffic and churn, and the compressed wire-volume cut.
//!
//! Three virtual-time legs plus one tabulation, all deterministic (the
//! latencies are modelled seconds, not wall-clock):
//!
//! 1. **Hot-shard burst** — 16 workers pull simultaneously from a
//!    4-shard tier on a 4×4 dragonfly. The pre-replication single home
//!    serializes every read at one host; the replicated deployment
//!    (R = 4, coalescing on) fans them across the groups. Asserts
//!    replicated mean *and* max pull latency ≤ single-home.
//! 2. **Churn** — a 2-replica plan loses a rank at the epoch boundary;
//!    pull latency after the departure must not exceed the pre-churn
//!    latency (crossing counts are priced from the *live* roster, the
//!    PR-5 fix).
//! 3. **Wire cut** — a compressed dcasgd engine run (top-k 0.1)
//!    through the full tier; asserts the run JSON's `ps.wire_cut_x`
//!    ≥ 3× (the dense-to-compressed byte ratio at the client legs).
//! 4. **Registry tabulation** — every engine in `engine_registry()` on
//!    a common config: simulated time, val err, and the ps block's
//!    wire accounting where the engine has one.
//!
//! `DCS3GD_BENCH_FAST=1` shrinks the engine-run step counts for smoke
//! runs. The JSON lands in `target/bench_results.json` under `"ps"`;
//! CI uploads it as `BENCH_ps.json`.

use std::collections::BTreeMap;

use dcs3gd::algo::{engine_registry, run_experiment};
use dcs3gd::bench_util::write_bench_json;
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::optim::MomentumSgd;
use dcs3gd::ps::{PsMode, PsTier, PsTierSpec, ReplicaPlan};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const N_PARAMS: usize = 4096;
const WORKERS: usize = 16;

fn fabric() -> NetModel {
    let d = Dragonfly { groups: 4, nodes_per_group: 4, ..Dragonfly::default() };
    NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 10e9, algo: AllReduceAlgo::Hierarchical(d) }
}

fn spawn_tier(plan: ReplicaPlan) -> PsTier {
    let init = vec![0.1f32; N_PARAMS];
    let spec = PsTierSpec {
        n_shards: 4,
        mode: PsMode::DcAsgd { lam0: 0.2 },
        net: fabric(),
        serve_s_per_elem: 2e-7,
        compress: Default::default(),
        seed: 17,
        capacity: WORKERS,
        plan,
    };
    PsTier::spawn(&init, spec, &mut |lo, hi| Box::new(MomentumSgd::new(hi - lo, 0.9)))
}

/// All 16 workers pull at the same virtual instant; returns
/// (mean, max) pull latency in modelled seconds.
fn pull_burst(tier: &PsTier) -> (f64, f64) {
    let mut clients: Vec<_> = (0..WORKERS).map(|r| tier.client(r)).collect();
    for (slot, c) in clients.iter_mut().enumerate() {
        c.rebind(slot, WORKERS);
    }
    let mut lat = Vec::with_capacity(WORKERS);
    for (w, c) in clients.iter_mut().enumerate() {
        lat.push(c.pull(w, 0.0).done_at);
    }
    let mean = lat.iter().sum::<f64>() / lat.len() as f64;
    let max = lat.iter().cloned().fold(0.0f64, f64::max);
    (mean, max)
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 12 } else { 60 };
    let mut section: BTreeMap<String, Json> = BTreeMap::new();

    // ---- 1. hot-shard pull burst: replicated vs single home -------
    let net = fabric();
    let full: Vec<usize> = (0..WORKERS).collect();
    let single = spawn_tier(ReplicaPlan::single_home(WORKERS));
    let (s_mean, s_max) = pull_burst(&single);
    single.shutdown();
    let replicated = spawn_tier(ReplicaPlan::place(
        4,
        &net,
        WORKERS,
        true,
        Vec::new(),
        vec![full.clone()],
    ));
    let (r_mean, r_max) = pull_burst(&replicated);
    replicated.shutdown();
    println!("# ps bench — pull latency, {WORKERS}-worker burst, {N_PARAMS} params, 4 shards\n");
    println!("{:<14} {:>12} {:>12}", "deployment", "mean", "max");
    println!("{:<14} {:>9.3} ms {:>9.3} ms", "single-home", s_mean * 1e3, s_max * 1e3);
    println!("{:<14} {:>9.3} ms {:>9.3} ms", "replicated x4", r_mean * 1e3, r_max * 1e3);
    assert!(
        r_mean <= s_mean && r_max <= s_max,
        "replicated pulls must not be slower than the single home: \
         mean {r_mean} vs {s_mean}, max {r_max} vs {s_max}"
    );
    let mut hot = BTreeMap::new();
    hot.insert("single_mean_s".into(), Json::Num(s_mean));
    hot.insert("single_max_s".into(), Json::Num(s_max));
    hot.insert("replicated_mean_s".into(), Json::Num(r_mean));
    hot.insert("replicated_max_s".into(), Json::Num(r_max));
    section.insert("hot_shard".into(), Json::Obj(hot));

    // ---- 2. pull latency across a departure boundary ---------------
    // Group 2 (ranks 8-11) leaves at t = 0.5 — workers that shared
    // worker 15's serving replica from a remote group. Its pull must
    // not get *more* expensive once the roster shrinks (crossings are
    // priced from live members only, the PR-5 fix).
    let shrunk: Vec<usize> = full.iter().copied().filter(|&r| r / 4 != 2).collect();
    let churn = spawn_tier(ReplicaPlan::place(
        2,
        &net,
        WORKERS,
        true,
        vec![0.5],
        vec![full.clone(), shrunk],
    ));
    let mut c = churn.client(15);
    c.rebind(15, WORKERS);
    let pre = c.pull(15, 0.0).done_at;
    let post = c.pull(15, 1.0).done_at - 1.0;
    drop(c);
    churn.shutdown();
    println!("\npull after churn: {:.3} ms -> {:.3} ms", pre * 1e3, post * 1e3);
    assert!(
        post <= pre,
        "pull latency grew after the roster shrank: {pre} -> {post}"
    );
    let mut ch = BTreeMap::new();
    ch.insert("pre_depart_s".into(), Json::Num(pre));
    ch.insert("post_depart_s".into(), Json::Num(post));
    section.insert("churn".into(), Json::Obj(ch));

    // ---- 3. compressed wire cut through the engine ------------------
    let cfg = ExperimentConfig::builder("linear")
        .name("ps_bench_wire")
        .algo(dcs3gd::algo::Algo::DcAsgd)
        .nodes(4)
        .local_batch(16)
        .steps(steps)
        .eta_single(0.02)
        .base_batch(16)
        .data(1024, 256, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .compress_topk(0.1)
        .ps_shards(2)
        .ps_replicas(2)
        .build();
    let report = run_experiment(&cfg).expect("compressed ps run");
    let ps = report.ps.as_ref().expect("ps block");
    let cut = ps.get("wire_cut_x").and_then(Json::as_f64).unwrap();
    println!(
        "\nwire cut at top-k 0.1: {cut:.1}x ({} -> {} bytes)",
        ps.get("dense_bytes").and_then(Json::as_f64).unwrap(),
        ps.get("wire_bytes").and_then(Json::as_f64).unwrap(),
    );
    assert!(cut >= 3.0, "top-k 0.1 must cut wire bytes >= 3x, got {cut}");
    section.insert("wire".into(), ps.clone());

    // ---- 4. the registry table --------------------------------------
    println!("\n{:<10} {:>10} {:>8} {:>10}", "engine", "sim", "val err", "wire cut");
    let mut rows = Vec::new();
    for spec in engine_registry() {
        let cfg = ExperimentConfig::builder("linear")
            .name(format!("ps_bench_{}", spec.name).leak())
            .algo(spec.algo)
            .nodes(4)
            .local_batch(8)
            .steps(if fast { 8 } else { 24 })
            .eta_single(0.02)
            .base_batch(32)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .compress_topk(0.1)
            .build();
        let r = run_experiment(&cfg).expect("registry run");
        let cut = r
            .ps
            .as_ref()
            .and_then(|p| p.get("wire_cut_x"))
            .and_then(Json::as_f64);
        println!(
            "{:<10} {:>8.4}s {:>8.3} {:>10}",
            spec.name,
            r.sim_time_s,
            r.final_val_err,
            cut.map(|c| format!("{c:.1}x")).unwrap_or_else(|| "-".into()),
        );
        let mut row = BTreeMap::new();
        row.insert("engine".into(), Json::Str(spec.name.into()));
        row.insert("sim_time_s".into(), Json::Num(r.sim_time_s));
        row.insert("final_val_err".into(), Json::Num(r.final_val_err as f64));
        if let Some(c) = cut {
            row.insert("wire_cut_x".into(), Json::Num(c));
        }
        rows.push(Json::Obj(row));
    }
    section.insert("engines".into(), Json::Arr(rows));

    let path = write_bench_json("ps", Json::Obj(section)).expect("bench json");
    println!("\nwrote {}", path.display());
}
