//! Bench: the elastic control plane — fixed-k vs adaptive-k DC-S3GD
//! under injected stragglers.
//!
//! For a sweep of straggler factors and network speeds, measures the
//! simulated wall-clock (and final loss) of the paper's static k = 1
//! against the `dss_pid` and `lambda_coupled` policies, plus the
//! closed-form bound per-step time = max(t_C_slow, t_AR / k*):
//! adapting k amortizes the collective across the window, so the win
//! grows as t_AR outpaces the straggler-bound compute time.
//!
//! ```sh
//! DCS3GD_BENCH_FAST=1 cargo bench --bench control
//! ```

use std::collections::BTreeMap;

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::bench_util::write_bench_json;
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::ControlPolicy;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const NODES: usize = 8;
const LOCAL_BATCH: usize = 32;
const SEC_PER_SAMPLE: f64 = 2e-4; // t_C = 6.4 ms/step per worker

fn run(policy: ControlPolicy, straggler: f64, beta: f64, steps: u64) -> RunReport {
    let mut compute = ComputeModel::uniform(SEC_PER_SAMPLE);
    if straggler > 1.0 {
        compute = compute.with_straggler(3, straggler, NODES);
    }
    let cfg = ExperimentConfig::builder("linear")
        .name(&format!("ctl_{}_s{straggler}_b{beta:.0e}", policy.name()))
        .algo(Algo::DcS3gd)
        .nodes(NODES)
        .local_batch(LOCAL_BATCH)
        .steps(steps)
        .eta_single(0.02)
        .base_batch(32)
        .data(4096, 512, 0.6)
        .compute(compute)
        .net(NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: beta, algo: AllReduceAlgo::Ring })
        .control_policy(policy)
        .k_bounds(1, 6)
        .build();
    run_experiment(&cfg).expect("run")
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 60 } else { 200 };
    let n_params = 769 * 10 + 10; // linear model on 16×16×3, 10 classes

    println!("# elastic control: fixed-k vs adaptive-k under stragglers\n");
    println!(
        "{:>6} {:>10} | {:>10} {:>10} {:>10} | {:>8} {:>8} | {:>7} {:>7} | {:>7}",
        "strag", "β B/s", "fixed", "dss_pid", "λ-coup", "speedup", "bound", "k_end", "λ_end", "Δloss%"
    );
    let mut rows: Vec<Json> = Vec::new();
    for &straggler in &[1.0f64, 1.5, 2.0, 4.0] {
        for &beta in &[1.2e6f64, 5e6] {
            let fixed = run(ControlPolicy::Fixed, straggler, beta, steps);
            let dss = run(ControlPolicy::DssPid, straggler, beta, steps);
            let lam = run(ControlPolicy::LambdaCoupled, straggler, beta, steps);

            // closed-form steady state: per-step max(t_C·strag, t_AR/k*)
            let net = NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: beta, algo: AllReduceAlgo::Ring };
            let t_ar = net.allreduce_time(n_params, NODES);
            let t_c_slow = SEC_PER_SAMPLE * LOCAL_BATCH as f64 * straggler;
            let k_star = (t_ar / t_c_slow).clamp(1.0, 6.0).ceil();
            let bound = t_c_slow.max(t_ar / k_star);

            let recs = dss.control.records();
            let k_end = recs.last().map(|r| r.k).unwrap_or(1);
            let lam_end =
                lam.control.records().last().map(|r| r.lam_scale).unwrap_or(1.0);
            let dloss = 100.0 * (dss.final_train_loss - fixed.final_train_loss)
                / fixed.final_train_loss;
            println!(
                "{straggler:>6.1} {beta:>10.0e} | {:>10.4} {:>10.4} {:>10.4} | {:>7.2}x {:>8.5} | {k_end:>7} {lam_end:>7.2} | {dloss:>6.1}%",
                fixed.mean_iter_time,
                dss.mean_iter_time,
                lam.mean_iter_time,
                fixed.mean_iter_time / dss.mean_iter_time,
                bound,
            );
            let mut row = BTreeMap::new();
            row.insert("straggler".to_string(), Json::Num(straggler));
            row.insert("beta_bytes_per_s".into(), Json::Num(beta));
            row.insert("fixed_iter_s".into(), Json::Num(fixed.mean_iter_time));
            row.insert("dss_pid_iter_s".into(), Json::Num(dss.mean_iter_time));
            row.insert("lambda_coupled_iter_s".into(), Json::Num(lam.mean_iter_time));
            row.insert(
                "speedup".into(),
                Json::Num(fixed.mean_iter_time / dss.mean_iter_time),
            );
            row.insert("bound_s".into(), Json::Num(bound));
            row.insert("k_end".into(), Json::Num(k_end as f64));
            row.insert("lam_end".into(), Json::Num(lam_end as f64));
            row.insert("dloss_pct".into(), Json::Num(dloss as f64));
            rows.push(Json::Obj(row));
        }
    }
    println!(
        "\nExpected: dss_pid tracks the closed-form bound (per-step →\n\
         max(t_C·strag, t_AR/k*)), beating fixed-k wherever the network\n\
         dominates the straggler; Δloss stays within a few percent —\n\
         the compensation (λ-coupled at deeper k) holds accuracy."
    );

    // Machine-readable export (the perf trajectory CI uploads).
    let mut section = BTreeMap::new();
    section.insert("steps".to_string(), Json::Num(steps as f64));
    section.insert("nodes".into(), Json::Num(NODES as f64));
    section.insert("policy_sweep".into(), Json::Arr(rows));
    let path = write_bench_json("control", Json::Obj(section)).expect("bench json");
    println!("bench JSON -> {}", path.display());
}
