//! Bench: the communication substrate.
//!
//! * wire-level ring and hierarchical all-reduce wall time vs payload
//!   size and rank count (the real data-movement paths of `comm::ring`
//!   and `comm::hier`),
//! * rendezvous-collective overhead (the semantics layer the engines use),
//! * the modelled t_AR across schedules — the numbers the Eq. 13/14
//!   analysis feeds on, including the ring-vs-hierarchical crossover
//!   the `schedule_coupled` control policy exploits: on the default
//!   dragonfly the hierarchical schedule beats the flat ring from
//!   N ≥ 256 at the ResNet-20 payload.

use std::collections::BTreeMap;

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::bench_util::{black_box, write_bench_json, Bencher};
use dcs3gd::comm::{
    hier::hier_network, ring::ring_network, AllReduceAlgo, Dragonfly, Group, NetModel,
};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::{Json, Rng};

/// ResNet-20 parameter count — the repo's canonical payload.
const RESNET20: usize = 271_690;

fn bench_ring(b: &mut Bencher, n_ranks: usize, len: usize) {
    b.bench_elems(&format!("ring/wire n={n_ranks} len={len}"), len, || {
        let comms = ring_network(n_ranks);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut rng = Rng::keyed(1, c.rank() as u64, 0);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf);
                    c.allreduce(&mut buf);
                    black_box(buf[0])
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn bench_hier(b: &mut Bencher, n_ranks: usize, nodes_per_group: usize, len: usize) {
    b.bench_elems(
        &format!("hier/wire n={n_ranks} m={nodes_per_group} len={len}"),
        len,
        || {
            let comms = hier_network(n_ranks, nodes_per_group);
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    std::thread::spawn(move || {
                        let mut rng = Rng::keyed(1, c.rank() as u64, 0);
                        let mut buf = vec![0.0f32; len];
                        rng.fill_normal(&mut buf);
                        let vol = c.allreduce(&mut buf);
                        black_box((buf[0], vol.local_elems + vol.global_elems))
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        },
    );
}

fn bench_rendezvous(b: &mut Bencher, n_ranks: usize, len: usize) {
    b.bench_elems(&format!("rendezvous n={n_ranks} len={len}"), len, || {
        let group = Group::new(n_ranks, NetModel::instant());
        let handles: Vec<_> = (0..n_ranks)
            .map(|r| {
                let mut c = group.comm(r);
                std::thread::spawn(move || {
                    let buf = vec![1.0f32; len];
                    let (sum, _) = c.allreduce(&buf, 0.0);
                    black_box(sum[0])
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn main() {
    println!("# allreduce bench — substrate cost (wall) + schedule models (sim)\n");
    let mut b = Bencher::from_env();
    for &n in &[2usize, 4, 8] {
        for &len in &[10_000usize, RESNET20] {
            bench_ring(&mut b, n, len);
        }
    }
    for &(n, m) in &[(8usize, 4usize), (8, 2)] {
        for &len in &[10_000usize, RESNET20] {
            bench_hier(&mut b, n, m, len);
        }
    }
    for &n in &[4usize, 8] {
        bench_rendezvous(&mut b, n, RESNET20);
    }
    b.report();

    println!("\n# modelled t_AR(n, N) (Aries-like defaults) — seconds");
    let net = NetModel::default();
    println!("{:>10} {:>6} {:>12} {:>12} {:>12}", "elems", "N", "ring", "tree", "flat");
    for &len in &[10_000usize, RESNET20, 25_600_000] {
        for &n in &[8usize, 32, 128] {
            let t = |algo| NetModel { algo, ..net }.allreduce_time(len, n);
            println!(
                "{len:>10} {n:>6} {:>12.3e} {:>12.3e} {:>12.3e}",
                t(AllReduceAlgo::Ring),
                t(AllReduceAlgo::Tree),
                t(AllReduceAlgo::Flat)
            );
        }
    }
    println!("\n(25.6M elems ≈ ResNet-50; flat column = the PS bottleneck)");

    // The acceptance table: flat ring vs hierarchical Layered-SGD on
    // the default dragonfly across 64–1024 simulated ranks, ResNet-20
    // payload. The hierarchical schedule amortizes the 2(N−1) latency
    // terms into 2(m−1) local + 2(G−1) global — the win the
    // schedule_coupled policy picks up at scale.
    println!("\n# ring vs hierarchical (default dragonfly links), {RESNET20} f32");
    println!(
        "{:>6} {:>6} {:>5} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "N", "G", "m", "t_ring", "t_hier", "local", "global", "speedup"
    );
    let mut any_win = false;
    let mut crossover_rows: Vec<Json> = Vec::new();
    for n in [64usize, 128, 256, 512, 1024] {
        let fly = Dragonfly::for_nodes(n);
        let ring = NetModel { algo: AllReduceAlgo::Ring, ..net }.allreduce_time(RESNET20, n);
        let phases = NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..net }
            .allreduce_phases(RESNET20, n);
        let speedup = ring / phases.total();
        any_win |= n >= 256 && speedup > 1.0;
        println!(
            "{n:>6} {:>6} {:>5} {ring:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {speedup:>7.2}x",
            fly.groups,
            fly.nodes_per_group,
            phases.total(),
            phases.local_s,
            phases.global_s,
        );
        let mut row = BTreeMap::new();
        row.insert("n_ranks".to_string(), Json::Num(n as f64));
        row.insert("t_ring_s".into(), Json::Num(ring));
        row.insert("t_hier_s".into(), Json::Num(phases.total()));
        row.insert("t_hier_local_s".into(), Json::Num(phases.local_s));
        row.insert("t_hier_global_s".into(), Json::Num(phases.global_s));
        row.insert("speedup".into(), Json::Num(speedup));
        crossover_rows.push(Json::Obj(row));
    }
    assert!(any_win, "hierarchical schedule must beat ring at >= 256 ranks");
    println!(
        "\n(speedup > 1 from N=256: the flat ring pays 2(N-1) latency terms,\n\
         the hierarchical schedule 2(m-1) local + 2(G-1) global — the\n\
         crossover the schedule_coupled control policy rides)"
    );

    // Contended vs dedicated: the same crossover under a tapered
    // per-group global fabric. The leader phases keep 2 flows in
    // flight per group, so taper >= 2 prices dedicated optics and
    // taper = 1 halves the effective global beta — the hierarchical
    // win must shift RIGHT as the taper drops (the contention-aware
    // pricing schedule_coupled now sees).
    println!("\n# contended vs dedicated global links (taper sweep), {RESNET20} f32");
    println!(
        "{:>6} {:>10} {:>14} {:>10} {:>14} {:>10}",
        "N", "ring", "hier(taper=2)", "speedup", "hier(taper=1)", "speedup"
    );
    let hier_at = |taper: usize, n: usize| {
        let fly = Dragonfly { global_taper: taper, ..Dragonfly::for_nodes(n) };
        NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..net }.allreduce_time(RESNET20, n)
    };
    let scales = [64usize, 128, 256, 512, 1024];
    let mut contended_rows: Vec<Json> = Vec::new();
    for n in scales {
        let ring = NetModel { algo: AllReduceAlgo::Ring, ..net }.allreduce_time(RESNET20, n);
        let (ded, con) = (hier_at(2, n), hier_at(1, n));
        println!(
            "{n:>6} {ring:>10.3e} {ded:>14.3e} {:>9.2}x {con:>14.3e} {:>9.2}x",
            ring / ded,
            ring / con,
        );
        let mut row = BTreeMap::new();
        row.insert("n_ranks".to_string(), Json::Num(n as f64));
        row.insert("t_ring_s".into(), Json::Num(ring));
        row.insert("t_hier_dedicated_s".into(), Json::Num(ded));
        row.insert("t_hier_taper1_s".into(), Json::Num(con));
        contended_rows.push(Json::Obj(row));
    }
    let crossover = |taper: usize| {
        scales.into_iter().find(|&n| {
            let ring =
                NetModel { algo: AllReduceAlgo::Ring, ..net }.allreduce_time(RESNET20, n);
            hier_at(taper, n) < ring
        })
    };
    let ded_cross = crossover(2).expect("dedicated hier must win somewhere in the sweep");
    let con_cross = crossover(1).expect("contended hier must still win at the top of the sweep");
    println!(
        "\ncrossover: dedicated (taper>=2) wins from N={ded_cross}, \
         taper=1 only from N={con_cross}"
    );
    assert!(
        con_cross > ded_cross,
        "contention must shift the hierarchical win right: \
         taper=1 crossover N={con_cross} vs dedicated N={ded_cross}"
    );

    // Engine rows: the crossover artifact now carries the windowed
    // engines — fixed-k DC-S3GD next to the per-worker-staleness
    // dyn_ssp and the randomized sgs — realized on the hierarchical
    // schedule the tables above price (linear backend, N=8).
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 12 } else { 40 };
    let fly = Dragonfly::for_nodes(8);
    println!("\n# engine rows on the default dragonfly (N=8, linear backend, sim seconds)");
    println!("{:>8} {:>12} {:>12} {:>10}", "engine", "iter_time", "sim_time", "val_err");
    let mut engine_rows: Vec<Json> = Vec::new();
    for algo in [Algo::Ssgd, Algo::DcS3gd, Algo::DynSsp, Algo::Sgs] {
        let cfg = ExperimentConfig::builder("linear")
            .name(format!("xover_{}", algo.name()).leak())
            .algo(algo)
            .nodes(8)
            .local_batch(16)
            .steps(steps)
            .eta_single(0.05)
            .base_batch(128)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .net(NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..net })
            .build();
        let rep = run_experiment(&cfg).expect("engine row run failed");
        println!(
            "{:>8} {:>11.3e}s {:>11.3e}s {:>9.1}%",
            algo.name(),
            rep.mean_iter_time,
            rep.sim_time_s,
            100.0 * rep.final_val_err
        );
        let mut row = BTreeMap::new();
        row.insert("engine".to_string(), Json::Str(algo.name().to_string()));
        row.insert("mean_iter_time_s".into(), Json::Num(rep.mean_iter_time));
        row.insert("sim_time_s".into(), Json::Num(rep.sim_time_s));
        row.insert("final_val_err".into(), Json::Num(rep.final_val_err as f64));
        engine_rows.push(Json::Obj(row));
    }

    // Machine-readable export: seeds the BENCH_*.json perf trajectory
    // (wall measurements + the modelled crossover tables), merged into
    // target/bench_results.json next to the control bench's section.
    let mut contention = BTreeMap::new();
    contention.insert("rows".to_string(), Json::Arr(contended_rows));
    contention.insert("crossover_dedicated_n".into(), Json::Num(ded_cross as f64));
    contention.insert("crossover_taper1_n".into(), Json::Num(con_cross as f64));
    let mut section = BTreeMap::new();
    section.insert("payload_elems".to_string(), Json::Num(RESNET20 as f64));
    section.insert("measurements".into(), b.results_json());
    section.insert("ring_vs_hier".into(), Json::Arr(crossover_rows));
    section.insert("contention".into(), Json::Obj(contention));
    section.insert("engines".into(), Json::Arr(engine_rows));
    let path = write_bench_json("allreduce", Json::Obj(section)).expect("bench json");
    println!("\nbench JSON -> {}", path.display());
}
