//! Bench: the communication substrate.
//!
//! * wire-level ring all-reduce wall time vs payload size and rank count
//!   (the real data-movement path of `comm::ring`),
//! * rendezvous-collective overhead (the semantics layer the engines use),
//! * the α-β model's predicted t_AR across algorithms — the numbers the
//!   Eq. 13/14 analysis feeds on.

use dcs3gd::bench_util::{black_box, Bencher};
use dcs3gd::comm::{ring::ring_network, AllReduceAlgo, Group, NetModel};
use dcs3gd::util::Rng;

fn bench_ring(b: &mut Bencher, n_ranks: usize, len: usize) {
    b.bench_elems(&format!("ring/wire n={n_ranks} len={len}"), len, || {
        let comms = ring_network(n_ranks);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut rng = Rng::keyed(1, c.rank() as u64, 0);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf);
                    c.allreduce(&mut buf);
                    black_box(buf[0])
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn bench_rendezvous(b: &mut Bencher, n_ranks: usize, len: usize) {
    b.bench_elems(&format!("rendezvous n={n_ranks} len={len}"), len, || {
        let group = Group::new(n_ranks, NetModel::instant());
        let handles: Vec<_> = (0..n_ranks)
            .map(|r| {
                let mut c = group.comm(r);
                std::thread::spawn(move || {
                    let buf = vec![1.0f32; len];
                    let (sum, _) = c.allreduce(&buf, 0.0);
                    black_box(sum[0])
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn main() {
    println!("# allreduce bench — substrate cost (wall) + α-β model (sim)\n");
    let mut b = Bencher::from_env();
    for &n in &[2usize, 4, 8] {
        for &len in &[10_000usize, 271_690] {
            // 271,690 = resnet20 parameter count
            bench_ring(&mut b, n, len);
        }
    }
    for &n in &[4usize, 8] {
        bench_rendezvous(&mut b, n, 271_690);
    }
    b.report();

    println!("\n# α-β model t_AR(n, N) (Aries-like defaults) — seconds");
    let net = NetModel::default();
    println!("{:>10} {:>6} {:>12} {:>12} {:>12}", "elems", "N", "ring", "tree", "flat");
    for &len in &[10_000usize, 271_690, 25_600_000] {
        for &n in &[8usize, 32, 128] {
            let t = |algo| NetModel { algo, ..net }.allreduce_time(len, n);
            println!(
                "{len:>10} {n:>6} {:>12.3e} {:>12.3e} {:>12.3e}",
                t(AllReduceAlgo::Ring),
                t(AllReduceAlgo::Tree),
                t(AllReduceAlgo::Flat)
            );
        }
    }
    println!("\n(25.6M elems ≈ ResNet-50; flat column = the PS bottleneck)");
}
