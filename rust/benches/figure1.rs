//! Bench (E2 + E4): Figure 1 error-curve series and the §III-D.2
//! distance-vs-N comparison.
//!
//! Emits per-epoch top-1 train error series for each (N, |B|) combo
//! (Figure 1's panels, as text + CSV in runs/fig1_bench/) and the
//! distance table supporting the paper's claim that DC-S3GD's
//! correction distance grows sub-linearly in N while DC-ASGD's grows
//! ~linearly.

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn cfg(algo: Algo, nodes: usize, local_batch: usize, steps: u64) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(format!("f1b_{}_n{}_lb{}", algo.name(), nodes, local_batch).leak())
        .algo(algo)
        .nodes(nodes)
        .local_batch(local_batch)
        .steps(steps)
        .eta_single(0.04)
        .base_batch(32)
        .data(8192, 1024, 2.0)
        .compute(ComputeModel::uniform(1e-4))
        .eval_every((steps / 8).max(1), 6)
        .out_dir("runs/fig1_bench")
        .build()
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 60 } else { 240 };

    println!("# Figure 1: top-1 train error per epoch, DC-S3GD vs SSGD\n");
    for &(nodes, lb) in &[(4usize, 32usize), (8, 32), (16, 32)] {
        let dc = run_experiment(&cfg(Algo::DcS3gd, nodes, lb, steps))?;
        let ss = run_experiment(&cfg(Algo::Ssgd, nodes, lb, steps))?;
        println!("== N={nodes} |B|={} ==", nodes * lb);
        println!("{:>6} {:>10} {:>10}", "epoch", "dcs3gd", "ssgd");
        let d = dc.recorder.epoch_train_err();
        let s = ss.recorder.epoch_train_err();
        for (epoch, derr) in &d {
            let serr = s.get(epoch).copied().unwrap_or(f32::NAN);
            println!("{epoch:>6} {:>9.1}% {:>9.1}%", derr * 100.0, serr * 100.0);
        }
        println!(
            "final val err: dcs3gd {:.1}% | ssgd {:.1}%\n",
            dc.final_val_err * 100.0,
            ss.final_val_err * 100.0
        );
    }

    println!("# §III-D.2: staleness distance vs N (E4)\n");
    println!(
        "{:>4} {:>16} {:>16} {:>10}",
        "N", "dcs3gd ‖D_i‖", "dcasgd ‖w_PS−w_i‖", "ratio"
    );
    let mut prev: Option<(f64, f64)> = None;
    for &nodes in &[2usize, 4, 8, 16] {
        let d = run_experiment(&cfg(Algo::DcS3gd, nodes, 32, steps.min(120)))?.mean_dist_to_avg;
        let a = run_experiment(&cfg(Algo::DcAsgd, nodes, 32, steps.min(120)))?.mean_dist_to_avg;
        let growth = prev
            .map(|(pd, pa)| format!("{:.2}/{:.2}", d / pd, a / pa))
            .unwrap_or_else(|| "-".into());
        println!("{nodes:>4} {d:>16.4e} {a:>16.4e} {growth:>10}");
        prev = Some((d, a));
    }
    println!(
        "\nratio column = per-doubling growth (dcs3gd/dcasgd): the paper\n\
         predicts the left factor stays well below the right.\n\
         CSV series in runs/fig1_bench/."
    );
    Ok(())
}
