//! Bench: the gradient-compression subsystem.
//!
//! * wall cost of the compressors themselves (top-k selection, QSGD
//!   quantization) on the ResNet-20 payload,
//! * the modelled **volume table**: per-rank wire bytes and t_AR per
//!   round for dense vs top-k vs QSGD on the ResNet-20 payload — the
//!   acceptance row asserts top-k at ratio ≤ 0.1 cuts the injected
//!   bytes per round ≥ 5× vs dense (and the gathered wire volume wins
//!   wherever ratio·N stays below the crossover),
//! * an end-to-end **volume-vs-convergence** table on the linear model:
//!   same step budget, dense vs top-k vs QSGD — sim wall-clock, wire
//!   bytes, final loss.
//!
//! ```sh
//! DCS3GD_BENCH_FAST=1 cargo bench --bench compress
//! ```

use std::collections::BTreeMap;

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::bench_util::{black_box, write_bench_json, Bencher};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::compress::{qsgd::qsgd_wire_elems, topk_k, CompressorKind, GradCompressor, Qsgd, TopK};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::{Json, Rng};

/// ResNet-20 parameter count — the repo's canonical payload.
const RESNET20: usize = 271_690;

fn e2e(kind: CompressorKind, ratio: f32, bits: u32, steps: u64) -> RunReport {
    let mut cfg = ExperimentConfig::builder("linear")
        .name(&format!("cmp_{}_{ratio}_{bits}", kind.name()))
        .algo(Algo::DcS3gd)
        .nodes(8)
        .local_batch(16)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(16)
        .data(4096, 512, 0.5)
        .compute(ComputeModel::uniform(2e-4))
        .net(NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 2e6, algo: AllReduceAlgo::Ring })
        .build();
    cfg.compress.kind = kind;
    cfg.compress.ratio = ratio;
    cfg.compress.bits = bits;
    run_experiment(&cfg).expect("run")
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 40 } else { 160 };

    println!("# gradient compression bench — compressor wall cost + wire volume + convergence\n");
    let mut b = Bencher::from_env();
    let mut grad = vec![0.0f32; RESNET20];
    Rng::new(1).fill_normal(&mut grad);
    let mut own = vec![0.0f32; RESNET20];
    for &ratio in &[0.1f32, 0.01] {
        let mut comp = TopK::new(RESNET20, ratio);
        b.bench_elems(&format!("topk/compress r={ratio} n={RESNET20}"), RESNET20, || {
            black_box(comp.compress(&grad, &mut own, 0).len());
        });
    }
    for &bits in &[8u32, 4] {
        let mut comp = Qsgd::new(RESNET20, bits, 1, 0);
        b.bench_elems(&format!("qsgd/compress b={bits} n={RESNET20}"), RESNET20, || {
            black_box(comp.compress(&grad, &mut own, 0).len());
        });
    }
    b.report();

    // Modelled volume table: per-rank injected wire bytes per round and
    // the modelled collective time on the default fabric. Dense rides
    // the ring all-reduce; top-k an all-gather of 2k per rank; QSGD the
    // dense reduce priced at bits/32.
    let net = NetModel::default();
    let n_ranks = 8usize;
    let dense_bytes = RESNET20 as f64 * 4.0;
    let t_dense = net.allreduce_time(RESNET20, n_ranks);
    println!("\n# modelled wire volume per round, ResNet-20 payload, N = {n_ranks}");
    println!(
        "{:<22} {:>14} {:>10} {:>12}",
        "scheme", "bytes/rank", "vs dense", "t_AR (s)"
    );
    println!("{:<22} {:>14.0} {:>9.1}x {:>12.3e}", "dense ring", dense_bytes, 1.0, t_dense);
    let mut volume_rows: Vec<Json> = Vec::new();
    let mut row = |scheme: &str, bytes: f64, t: f64| {
        println!(
            "{scheme:<22} {bytes:>14.0} {:>9.1}x {t:>12.3e}",
            dense_bytes / bytes.max(1e-30),
        );
        let mut m = BTreeMap::new();
        m.insert("scheme".to_string(), Json::Str(scheme.to_string()));
        m.insert("bytes_per_rank".into(), Json::Num(bytes));
        m.insert("reduction_x".into(), Json::Num(dense_bytes / bytes.max(1e-30)));
        m.insert("t_ar_s".into(), Json::Num(t));
        volume_rows.push(Json::Obj(m));
    };
    let mut topk_reduction_at_01 = 0.0;
    for &ratio in &[0.1f32, 0.05, 0.01] {
        let wire = 2 * topk_k(RESNET20, ratio);
        let bytes = wire as f64 * 4.0;
        let t = net.allgather_time(wire, n_ranks);
        row(&format!("topk r={ratio}"), bytes, t);
        if ratio == 0.1 {
            topk_reduction_at_01 = dense_bytes / bytes;
        }
    }
    for &bits in &[8u32, 4] {
        let wire = qsgd_wire_elems(RESNET20, bits);
        row(&format!("qsgd b={bits}"), wire as f64 * 4.0, net.allreduce_time(wire, n_ranks));
    }
    // Acceptance: top-k at ratio ≤ 0.1 must cut the injected bytes per
    // round at least 5× vs dense (indices double the payload, so the
    // reduction is 1/(2·ratio) — ≥ 5 for every ratio ≤ 0.1).
    assert!(
        topk_reduction_at_01 >= 5.0 - 1e-9,
        "top-k at ratio 0.1 must reduce wire bytes >= 5x, got {topk_reduction_at_01:.2}x"
    );
    // and the gathered sparse round is modelled cheaper than the dense
    // ring wherever ratio·N stays well below 1
    let sparse_t = net.allgather_time(2 * topk_k(RESNET20, 0.01), n_ranks);
    assert!(
        sparse_t < t_dense,
        "sparse all-gather at 1% must beat the dense ring: {sparse_t} vs {t_dense}"
    );
    println!(
        "\n(top-k injects 2k elements per rank — 1/(2·ratio) less than dense —\n\
         and its all-gather wins the modelled t_AR while ratio·N < crossover;\n\
         QSGD keeps the dense reduce at bits/32 of the bytes)"
    );

    // End-to-end volume-vs-convergence on the linear model: same step
    // budget on a slow fabric; compression buys simulated wall-clock,
    // error feedback holds the loss.
    println!("\n# end-to-end: dense vs compressed DC-S3GD ({steps} steps, slow ring)");
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>10}",
        "scheme", "wire B/round", "sim time", "final loss", "val err"
    );
    let mut e2e_rows: Vec<Json> = Vec::new();
    let schemes: Vec<(String, RunReport)> = vec![
        ("dense".to_string(), e2e(CompressorKind::None, 0.05, 8, steps)),
        ("topk r=0.05".to_string(), e2e(CompressorKind::TopK, 0.05, 8, steps)),
        ("topk r=0.01".to_string(), e2e(CompressorKind::TopK, 0.01, 8, steps)),
        ("qsgd b=8".to_string(), e2e(CompressorKind::Qsgd, 0.05, 8, steps)),
    ];
    let dense_time = schemes[0].1.sim_time_s;
    let dense_loss = schemes[0].1.final_train_loss;
    for (name, r) in &schemes {
        let s = r.control.compress_summary();
        println!(
            "{name:<16} {:>12.0} {:>11.4}s {:>12.4} {:>9.1}%",
            s.mean_wire_bytes(),
            r.sim_time_s,
            r.final_train_loss,
            100.0 * r.final_val_err,
        );
        let mut m = BTreeMap::new();
        m.insert("scheme".to_string(), Json::Str(name.clone()));
        m.insert("mean_wire_bytes".into(), Json::Num(s.mean_wire_bytes()));
        m.insert("sim_time_s".into(), Json::Num(r.sim_time_s));
        m.insert("final_train_loss".into(), Json::Num(r.final_train_loss as f64));
        m.insert("final_val_err".into(), Json::Num(r.final_val_err as f64));
        e2e_rows.push(Json::Obj(m));
    }
    let topk01 = &schemes[2].1;
    assert!(
        topk01.sim_time_s < dense_time,
        "top-k 1% must buy wall-clock on a slow fabric: {} vs dense {}",
        topk01.sim_time_s,
        dense_time
    );
    assert!(
        topk01.final_train_loss < dense_loss * 1.5 + 0.25,
        "top-k 1% fell out of the dense loss envelope: {} vs {}",
        topk01.final_train_loss,
        dense_loss
    );

    // Machine-readable export, merged into target/bench_results.json
    // next to the allreduce/control sections (the CI perf artifact).
    let mut section = BTreeMap::new();
    section.insert("payload_elems".to_string(), Json::Num(RESNET20 as f64));
    section.insert("steps".into(), Json::Num(steps as f64));
    section.insert("measurements".into(), b.results_json());
    section.insert("volume".into(), Json::Arr(volume_rows));
    section.insert("e2e".into(), Json::Arr(e2e_rows));
    let path = write_bench_json("compress", Json::Obj(section)).expect("bench json");
    println!("\nbench JSON -> {}", path.display());
}
