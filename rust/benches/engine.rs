//! Bench: the parallel engine core — *simulator* throughput.
//!
//! Every other bench measures the modelled fabric (sim seconds) or a
//! kernel in isolation; this lane measures the engine itself: wall
//! seconds per run, steps/sec and rank·steps/sec, and the
//! serial-vs-parallel speedup table at 16/64/256 ranks that the
//! `[perf]` worker pool buys. Each scale runs the same golden config
//! twice — `threads = 1` (true serial scheduling) and `threads = 0`
//! (auto) — and asserts the determinism contract the pool promises:
//! byte-identical run JSON (minus the `"perf"` block) and identical
//! epoch param CRCs.
//!
//! The speedup assertion is hardware-conditional: it engages when
//! `DCS3GD_ENGINE_MIN_SPEEDUP` is set (CI pins 2.0 on its 2-vCPU
//! runner) or when the host has ≥ 8 cores (then the ISSUE's 4× gate
//! applies at 64 ranks); on smaller hosts the table is reported only —
//! a 1-core box cannot express parallel speedup.
//!
//! `DCS3GD_BENCH_FAST=1` shrinks the step counts for smoke runs. The
//! JSON lands in `target/bench_results.json` under `"engine"`; CI
//! uploads it as `BENCH_engine.json`.

use std::collections::BTreeMap;

use dcs3gd::algo::{engine_registry, run_experiment, Algo, RunReport, WorkerHarness};
use dcs3gd::bench_util::write_bench_json;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::exec::resolve_threads;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

fn golden_cfg(nodes: usize, steps: u64, threads: usize) -> ExperimentConfig {
    // The ResNet-20 artifact when lowered, the linear backend otherwise
    // (same fallback as benches/table1.rs) — the engine mechanics under
    // test are identical.
    let variant = if std::path::Path::new("artifacts/resnet20_b32/meta.json").exists() {
        "resnet20_b32"
    } else {
        "linear"
    };
    let local_batch = if variant == "linear" { 16 } else { 32 };
    ExperimentConfig::builder(variant)
        .name(format!("engine_n{nodes}_t{threads}").leak())
        .algo(Algo::DcS3gd)
        .nodes(nodes)
        .local_batch(local_batch)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(256)
        .data(4096, 512, 1.0)
        .compute(ComputeModel::uniform(1e-3))
        .threads(threads)
        .build()
}

/// Run one config and hand back (report, deterministic JSON text,
/// epoch CRC vector) — everything the differential needs.
fn run_once(cfg: &ExperimentConfig) -> (RunReport, String, Vec<u64>) {
    let report = run_experiment(cfg).expect("engine bench run failed");
    let json = report.deterministic_json().to_string();
    let crcs: Vec<u64> = report.epochs.records().iter().map(|r| r.w_crc).collect();
    (report, json, crcs)
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 10 } else { 40 };
    let auto = resolve_threads(0);
    let min_speedup: Option<f64> = match std::env::var("DCS3GD_ENGINE_MIN_SPEEDUP") {
        Ok(v) => Some(v.parse().expect("DCS3GD_ENGINE_MIN_SPEEDUP must be a float")),
        Err(_) if auto >= 8 => Some(4.0),
        Err(_) => None,
    };

    let n_params = WorkerHarness::prepare(&golden_cfg(2, 1, 1)).expect("harness").n_params();
    println!("# engine bench — simulator wall-clock (auto = {auto} threads, {n_params} params)\n");
    println!(
        "{:>6} {:>6} {:>11} {:>11} {:>8} {:>12} {:>14} {:>5}",
        "N", "steps", "serial", "parallel", "speedup", "steps/s", "rank·steps/s", "bitid"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_at_64 = f64::NAN;
    for &nodes in &[16usize, 64, 256] {
        let (ser, ser_json, ser_crcs) = run_once(&golden_cfg(nodes, steps, 1));
        let (par, par_json, par_crcs) = run_once(&golden_cfg(nodes, steps, 0));

        // The determinism contract: the pool moves wall-clock only.
        assert_eq!(
            ser_json, par_json,
            "N={nodes}: parallel run JSON diverged from serial (minus \"perf\")"
        );
        assert_eq!(ser_crcs, par_crcs, "N={nodes}: epoch param CRCs diverged");

        let speedup = ser.wall_time_s / par.wall_time_s;
        if nodes == 64 {
            speedup_at_64 = speedup;
        }
        let steps_per_s = steps as f64 / par.wall_time_s;
        let rank_steps_per_s = (nodes as u64 * steps) as f64 / par.wall_time_s;
        println!(
            "{nodes:>6} {steps:>6} {:>10.3}s {:>10.3}s {speedup:>7.2}x {steps_per_s:>12.1} {rank_steps_per_s:>14.1} {:>5}",
            ser.wall_time_s, par.wall_time_s, "yes"
        );

        let mut row = BTreeMap::new();
        row.insert("n_ranks".to_string(), Json::Num(nodes as f64));
        row.insert("steps".into(), Json::Num(steps as f64));
        row.insert("serial_wall_s".into(), Json::Num(ser.wall_time_s));
        row.insert("parallel_wall_s".into(), Json::Num(par.wall_time_s));
        row.insert("speedup".into(), Json::Num(speedup));
        row.insert("steps_per_s".into(), Json::Num(steps_per_s));
        row.insert("rank_steps_per_s".into(), Json::Num(rank_steps_per_s));
        row.insert("bit_identical".into(), Json::Bool(true));
        rows.push(Json::Obj(row));
    }

    // Engine rows from the registry: wall-clock throughput of every
    // bench-table engine on the 64-rank geometry (parallel pool) —
    // the same one list `benches/table1.rs` and `benches/hetero.rs`
    // iterate.
    println!("\n{:>8} {:>10} {:>12}", "engine", "wall", "steps/s");
    let mut engine_rows: Vec<Json> = Vec::new();
    for spec in engine_registry().iter().filter(|e| e.bench_row) {
        let mut cfg = golden_cfg(64, steps, 0);
        cfg.algo = spec.algo;
        cfg.name = format!("engine_{}_n64", spec.name);
        let (rep, _, _) = run_once(&cfg);
        let steps_per_s = steps as f64 / rep.wall_time_s;
        println!("{:>8} {:>9.3}s {steps_per_s:>12.1}", spec.name, rep.wall_time_s);
        let mut row = BTreeMap::new();
        row.insert("engine".to_string(), Json::Str(spec.name.to_string()));
        row.insert("wall_s".into(), Json::Num(rep.wall_time_s));
        row.insert("steps_per_s".into(), Json::Num(steps_per_s));
        engine_rows.push(Json::Obj(row));
    }

    // ----------------------------------------------------------------
    // Trace-overhead gate: the obs journal must be near-free. Same
    // 64-rank golden config with tracing at the default capacity vs
    // `trace.capacity = 0` (fully disabled), best-of-2 wall each; the
    // traced run must keep within DCS3GD_TRACE_MAX_OVERHEAD (default
    // 5%) of the untraced steps/s — and the deterministic run JSON must
    // be byte-identical whether tracing is on or off.
    // ----------------------------------------------------------------
    let max_overhead: f64 = std::env::var("DCS3GD_TRACE_MAX_OVERHEAD")
        .ok()
        .map(|v| v.parse().expect("DCS3GD_TRACE_MAX_OVERHEAD must be a float"))
        .unwrap_or(0.05);
    let traced_cfg = || {
        let mut cfg = golden_cfg(64, steps, 0);
        cfg.name = "engine_trace_on_n64".into();
        cfg
    };
    let untraced_cfg = || {
        let mut cfg = golden_cfg(64, steps, 0);
        cfg.name = "engine_trace_off_n64".into();
        cfg.trace.capacity = 0;
        cfg
    };
    let best_of2 = |mk: &dyn Fn() -> ExperimentConfig| {
        let (a, ja, _) = run_once(&mk());
        let (b, _, _) = run_once(&mk());
        (a.wall_time_s.min(b.wall_time_s), ja, a)
    };
    let (wall_on, json_on, rep_on) = best_of2(&traced_cfg);
    let (wall_off, json_off, _) = best_of2(&untraced_cfg);
    // Names differ between the two configs, so compare everything else.
    let strip_name = |j: &str, name: &str| j.replace(&format!("\"{name}\""), "\"engine\"");
    assert_eq!(
        strip_name(&json_on, "engine_trace_on_n64"),
        strip_name(&json_off, "engine_trace_off_n64"),
        "deterministic run JSON must not change when tracing toggles"
    );
    let obs = rep_on.obs.as_ref().expect("traced run carries the obs hub");
    assert!(!obs.journal.is_empty(), "traced run recorded no events");
    let (sps_on, sps_off) = (steps as f64 / wall_on, steps as f64 / wall_off);
    let overhead = (sps_off - sps_on) / sps_off;
    println!(
        "\ntrace overhead: {:.1} steps/s traced vs {:.1} untraced ({:+.2}% — gate {:.0}%, \
         {} events journaled)",
        sps_on,
        sps_off,
        100.0 * overhead,
        100.0 * max_overhead,
        obs.journal.len(),
    );
    assert!(
        overhead <= max_overhead,
        "tracing costs {:.2}% steps/s, over the {:.0}% gate",
        100.0 * overhead,
        100.0 * max_overhead
    );
    let mut trace_row = BTreeMap::new();
    trace_row.insert("steps_per_s_traced".to_string(), Json::Num(sps_on));
    trace_row.insert("steps_per_s_untraced".into(), Json::Num(sps_off));
    trace_row.insert("overhead_frac".into(), Json::Num(overhead));
    trace_row.insert("max_overhead_frac".into(), Json::Num(max_overhead));
    trace_row.insert("journal_events".into(), Json::Num(obs.journal.len() as f64));

    if let Some(min) = min_speedup {
        assert!(
            speedup_at_64 >= min,
            "64-rank parallel speedup {speedup_at_64:.2}x under the {min:.2}x floor \
             (threads auto = {auto})"
        );
        println!("\nspeedup floor {min:.2}x at 64 ranks: met ({speedup_at_64:.2}x)");
    } else {
        println!(
            "\n(speedup floor not asserted: {auto} thread(s) available and \
             DCS3GD_ENGINE_MIN_SPEEDUP unset)"
        );
    }

    let mut section = BTreeMap::new();
    section.insert("threads_auto".to_string(), Json::Num(auto as f64));
    section.insert("n_params".into(), Json::Num(n_params as f64));
    section.insert("steps".into(), Json::Num(steps as f64));
    section.insert(
        "min_speedup_asserted".into(),
        min_speedup.map(Json::Num).unwrap_or(Json::Null),
    );
    section.insert("rows".into(), Json::Arr(rows));
    section.insert("engines".into(), Json::Arr(engine_rows));
    section.insert("trace_overhead".into(), Json::Obj(trace_row));
    let path = write_bench_json("engine", Json::Obj(section)).expect("bench json");
    println!("bench JSON -> {}", path.display());
}
