//! Bench: the delay-compensated update hot path (L1/L3 comparison).
//!
//! * fused single-pass rust kernel vs the naive multi-pass composition
//!   (the §Perf optimization this repo ships),
//! * the reductions (norms) needed for Eq. 17,
//! * the AOT Pallas `dc_step` artifact through PJRT, when present —
//!   the L1 kernel's end-to-end cost including runtime overhead.

use dcs3gd::bench_util::{black_box, Bencher};
use dcs3gd::dc::{self, DcHyper};
use dcs3gd::runtime::ComputeServer;
use dcs3gd::tensor;
use dcs3gd::util::Rng;

fn randvec(seed: u64, n: usize) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut v = vec![0.0; n];
    r.fill_normal(&mut v);
    v
}

fn main() {
    let mut b = Bencher::from_env();
    let hp = DcHyper { eta: 0.1, mu: 0.9, lam0: 0.2, wd: 1e-4 };

    for &n in &[10_218usize, 271_690, 4_000_000] {
        // 10,218 / 271,690 = tiny_cnn / resnet20 param counts; 4M ≈ a
        // small production model.
        let g = randvec(1, n);
        let d = randvec(2, n);

        {
            let (mut v, mut w, mut dw) = (randvec(3, n), randvec(4, n), vec![0.0; n]);
            b.bench_elems(&format!("dc/fused n={n}"), n, || {
                black_box(dc::dc_correct_update(
                    &g,
                    Some(&d),
                    &mut v,
                    &mut w,
                    None,
                    hp,
                    &mut dw,
                ));
            });
        }

        {
            // naive: λ (2 reduction passes) + correct (1 pass) + momentum
            // (1 pass) + Δw apply (2 passes) — what an unfused
            // implementation does.
            let (mut v, mut w, mut dw) = (randvec(3, n), randvec(4, n), vec![0.0; n]);
            let mut gt = vec![0.0; n];
            b.bench_elems(&format!("dc/naive n={n}"), n, || {
                let lam = dc::dynamic_lambda(&g, &d, hp.lam0);
                dc::dc_correct(&g, &d, lam, &mut gt);
                for i in 0..n {
                    v[i] = hp.mu * v[i] + gt[i] + hp.wd * w[i];
                    dw[i] = -hp.eta * v[i];
                }
                tensor::add_assign(&mut w, &d);
                tensor::add_assign(&mut w, &dw);
                black_box(w[0]);
            });
        }

        b.bench_elems(&format!("dc/lambda-reductions n={n}"), n, || {
            black_box(dc::dynamic_lambda(&g, &d, hp.lam0));
        });
    }

    // The Pallas kernel through PJRT (L1 + runtime overhead).
    let variant = std::path::Path::new("artifacts/tiny_cnn_b32");
    if variant.join("meta.json").exists() {
        let server = ComputeServer::start(variant).expect("compute server");
        let n = server.meta().param_count;
        let g = randvec(1, n);
        let d = randvec(2, n);
        let v = randvec(3, n);
        let w = randvec(4, n);
        b.bench_elems(&format!("dc/pallas-pjrt n={n}"), n, || {
            black_box(server.dc_step(&g, &d, &v, &w, 0.1, 0.9, 0.2, 1e-4).unwrap());
        });
    } else {
        eprintln!("(skipping pallas-pjrt: run `make artifacts`)");
    }

    b.report();
    println!(
        "\nroofline note: fused reads 4n f32 + writes 3n (incl. w) = 28n B per\n\
         update + one 2n-read reduction pass for λ; naive adds 3 extra\n\
         passes. Ratio fused/naive below ~0.7 means the fusion is paying."
    );
}
