//! Bench: the event-queue core at fleet scale — crossover tables to
//! 1M ranks.
//!
//! The rendezvous substrate materializes a thread per rank and tops
//! out near N ≈ 1024; this lane drives the cohort-folded event core
//! (`comm::event::CohortSim`) through the same flat-vs-hierarchical
//! and contention crossovers at N = 1k → 1M:
//!
//! * the **closed-form crossover table**: modelled t_AR for the flat
//!   ring vs the hierarchical Layered-SGD schedule (dedicated and
//!   taper-1 contended global optics) on `Dragonfly::for_nodes(N)`
//!   geometries, ResNet-20 payload,
//! * the **event-core tabulation**: a mixed-tier spot fleet with
//!   scripted probes/quarantines/joins run through `CohortSim` at
//!   every N — wall-clock per scenario is the acceptance number: the
//!   three largest scales (65k, 262k, 1M) must tabulate in **under
//!   60 s total**, and the folded arena must stay event-bounded
//!   (materialized ranks ≪ N) at 1M,
//! * the **differential spot-check**: folded vs `materialize_all`
//!   traces bit-identical at N = 1024 (the full scenario matrix lives
//!   in `tests/proptest_invariants.rs`).
//!
//! `DCS3GD_BENCH_FAST=1` shrinks the round counts only — the N grid is
//! the point of this bench and never shrinks. JSON lands in
//! `target/bench_results.json` under `"scale"`; CI uploads it as
//! `BENCH_scale.json`.

use std::collections::BTreeMap;
use std::time::Instant;

use dcs3gd::bench_util::write_bench_json;
use dcs3gd::comm::event::{CohortSim, FleetEvent, FleetEventKind, ScaleScenario};
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel};
use dcs3gd::hetero::HeteroConfig;
use dcs3gd::util::Json;

/// ResNet-20 parameter count — the repo's canonical payload.
const RESNET20: usize = 271_690;

/// The fleet-scale N grid. Never shrunk by fast mode: tabulating the
/// top three scales inside the wall-clock ceiling IS the acceptance.
const GRID: [usize; 6] = [1024, 4096, 16_384, 65_536, 262_144, 1_048_576];

/// Wall-clock ceiling (seconds) for the 65k + 262k + 1M event-core
/// tabulations together — the ISSUE's "tabulates in seconds" gate.
const CEILING_S: f64 = 60.0;

/// The mixed-tier spot fleet every scale runs: three GPU generations,
/// an N-independent expected spot cohort (so the materialized arena is
/// event-bounded, not fleet-bounded), no diurnal (diurnal fleets run
/// fully materialized by design — that regime belongs to the
/// rendezvous substrate's scales).
fn fleet(n_ranks: usize) -> HeteroConfig {
    HeteroConfig {
        enabled: true,
        tiers: vec![1.0, 1.4, 2.2],
        // ~96 expected spot ranks at every N (capped for the small end).
        spot_fraction: (96.0 / n_ranks as f64).min(0.25),
        spot_mtbf_s: 0.05,
        spot_correlation: 0.3,
        ..HeteroConfig::default()
    }
}

fn scenario(n_ranks: usize, rounds: u64) -> ScaleScenario {
    let fly = Dragonfly::for_nodes(n_ranks);
    let net = NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..NetModel::default() };
    let mut sc = ScaleScenario::uniform(n_ranks, RESNET20, 1e-3, net);
    sc.rounds = rounds;
    sc.hetero = fleet(n_ranks);
    sc.seed = 11;
    sc.events = vec![
        FleetEvent { kind: FleetEventKind::Probe, rank: 1, at_s: 0.002 },
        FleetEvent { kind: FleetEventKind::Quarantine, rank: 2, at_s: 0.004 },
        FleetEvent { kind: FleetEventKind::Join, rank: n_ranks, at_s: 0.006 },
        FleetEvent { kind: FleetEventKind::Probe, rank: n_ranks / 2, at_s: 0.008 },
    ];
    sc
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let rounds: u64 = if fast { 8 } else { 32 };
    let net = NetModel::default();

    // ----------------------------------------------------------------
    // Closed-form crossover table: flat ring vs hierarchical, dedicated
    // and contended global optics, to 1M ranks.
    // ----------------------------------------------------------------
    println!("# scale bench — crossovers and the event core at 1k → 1M ranks\n");
    println!("# modelled flat-vs-hier crossover, {RESNET20} f32");
    println!(
        "{:>8} {:>6} {:>6} {:>12} {:>12} {:>8} {:>14} {:>8}",
        "N", "G", "m", "t_ring", "t_hier", "speedup", "hier(taper=1)", "speedup"
    );
    let hier_at = |taper: usize, n: usize| {
        let fly = Dragonfly { global_taper: taper, ..Dragonfly::for_nodes(n) };
        NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..net }.allreduce_time(RESNET20, n)
    };
    let ring_at =
        |n: usize| NetModel { algo: AllReduceAlgo::Ring, ..net }.allreduce_time(RESNET20, n);
    let mut crossover_rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<f64> = Vec::new();
    for &n in &GRID {
        let fly = Dragonfly::for_nodes(n);
        let ring = ring_at(n);
        let (ded, con) = (hier_at(2, n), hier_at(1, n));
        println!(
            "{n:>8} {:>6} {:>6} {ring:>12.3e} {ded:>12.3e} {:>7.2}x {con:>14.3e} {:>7.2}x",
            fly.groups,
            fly.nodes_per_group,
            ring / ded,
            ring / con,
        );
        speedups.push(ring / ded);
        let mut row = BTreeMap::new();
        row.insert("n_ranks".to_string(), Json::Num(n as f64));
        row.insert("groups".into(), Json::Num(fly.groups as f64));
        row.insert("nodes_per_group".into(), Json::Num(fly.nodes_per_group as f64));
        row.insert("t_ring_s".into(), Json::Num(ring));
        row.insert("t_hier_s".into(), Json::Num(ded));
        row.insert("t_hier_taper1_s".into(), Json::Num(con));
        row.insert("speedup".into(), Json::Num(ring / ded));
        row.insert("speedup_taper1".into(), Json::Num(ring / con));
        crossover_rows.push(Json::Obj(row));
    }
    // At fleet scale the flat ring's 2(N−1) latency terms are the whole
    // story: the hierarchical schedule must win at every tabulated
    // scale from 65k up, dedicated and contended alike, and the win
    // must widen with N.
    for (&n, w) in GRID.iter().zip(&speedups) {
        if n >= 65_536 {
            assert!(*w > 1.0, "hierarchical must beat ring at N={n}: {w:.2}x");
            assert!(
                hier_at(1, n) < ring_at(n),
                "even taper-1 contended hier must beat ring at N={n}"
            );
        }
    }
    assert!(
        speedups.last().unwrap() > speedups.first().unwrap(),
        "the hierarchical win must widen from 1k to 1M ranks"
    );

    // ----------------------------------------------------------------
    // The event core at every scale: wall-clock to tabulate the fleet.
    // ----------------------------------------------------------------
    println!("\n# event core: mixed-tier spot fleet, {rounds} rounds");
    println!(
        "{:>8} {:>8} {:>9} {:>8} {:>12} {:>10}",
        "N", "cohorts", "arena", "contrib", "t_complete", "wall"
    );
    let mut core_rows: Vec<Json> = Vec::new();
    let mut top3_wall_s = 0.0f64;
    let fold_metrics = dcs3gd::obs::Metrics::new();
    for &n in &GRID {
        let start = Instant::now();
        let mut sim = CohortSim::new(scenario(n, rounds));
        let trace = sim.run();
        let wall = start.elapsed().as_secs_f64();
        if n >= 65_536 {
            top3_wall_s += wall;
        }
        let last = trace.last().expect("rounds >= 1");
        let arena_max = trace.iter().map(|s| s.materialized).max().unwrap();
        let fold = sim.stats();
        sim.export_obs(&fold_metrics);
        println!(
            "{n:>8} {:>8} {arena_max:>9} {:>8} {:>11.4}s {:>9.3}s",
            sim.n_cohorts(),
            last.contributors,
            last.t_complete,
            wall
        );
        // The fold criterion is the point: the arena is bounded by the
        // event population (spot cohort + scripted events), never by N.
        // The sim's own lifetime accounting must agree with the trace
        // and stay event-bounded too: every materialization is paid for
        // by an event, every refold by a prior split.
        assert!(
            arena_max <= 512,
            "N={n}: materialized arena {arena_max} is not event-bounded"
        );
        assert!(
            fold.arena_max <= 512,
            "N={n}: fold-stats arena high-water {} is not event-bounded",
            fold.arena_max
        );
        assert!(fold.arena_max >= arena_max, "stats high-water below the trace's");
        assert!(
            fold.refolds <= fold.events_total,
            "N={n}: {} refolds exceed the {} events that can split",
            fold.refolds,
            fold.events_total
        );
        assert!(fold.events_applied <= fold.events_total);
        let mut row = BTreeMap::new();
        row.insert("n_ranks".to_string(), Json::Num(n as f64));
        row.insert("rounds".into(), Json::Num(rounds as f64));
        row.insert("wall_s".into(), Json::Num(wall));
        row.insert("arena_max".into(), Json::Num(arena_max as f64));
        row.insert("fold_arena_max".into(), Json::Num(fold.arena_max as f64));
        row.insert("fold_refolds".into(), Json::Num(fold.refolds as f64));
        row.insert("fold_events_applied".into(), Json::Num(fold.events_applied as f64));
        row.insert("fold_events_total".into(), Json::Num(fold.events_total as f64));
        row.insert("contributors_final".into(), Json::Num(last.contributors as f64));
        row.insert("t_complete_s".into(), Json::Num(last.t_complete));
        core_rows.push(Json::Obj(row));
    }
    println!(
        "fold accounting (obs counters): arena high-water {} | refolds {} | events {}/{}",
        fold_metrics.counter("sim.cohort.arena_max"),
        fold_metrics.counter("sim.cohort.refolds"),
        fold_metrics.counter("sim.cohort.events_applied"),
        fold_metrics.counter("sim.cohort.events_total"),
    );
    assert!(
        top3_wall_s < CEILING_S,
        "65k + 262k + 1M tabulations took {top3_wall_s:.1}s, ceiling {CEILING_S}s"
    );
    println!(
        "\n(65k + 262k + 1M tabulated in {top3_wall_s:.2}s — ceiling {CEILING_S:.0}s; \
         the rendezvous substrate tops out near N=1024)"
    );

    // ----------------------------------------------------------------
    // Differential spot-check at the dense frontier.
    // ----------------------------------------------------------------
    let sc = scenario(1024, rounds);
    let folded = CohortSim::new(sc.clone()).run();
    let dense = CohortSim::materialize_all(sc).run();
    assert_eq!(folded.len(), dense.len());
    for (f, d) in folded.iter().zip(&dense) {
        assert_eq!(f.round, d.round);
        assert_eq!(f.contributors, d.contributors, "round {}", f.round);
        assert!(
            f.t_complete.to_bits() == d.t_complete.to_bits(),
            "round {}: folded t_complete {} != dense {}",
            f.round,
            f.t_complete,
            d.t_complete
        );
    }
    println!("differential: folded == dense (bit-identical) over {} rounds at N=1024", rounds);

    // Machine-readable export, merged into target/bench_results.json
    // (CI uploads it as BENCH_scale.json).
    let mut section = BTreeMap::new();
    section.insert("payload_elems".to_string(), Json::Num(RESNET20 as f64));
    section.insert("rounds".into(), Json::Num(rounds as f64));
    section.insert("crossover".into(), Json::Arr(crossover_rows));
    section.insert("event_core".into(), Json::Arr(core_rows));
    section.insert("top3_wall_s".into(), Json::Num(top3_wall_s));
    section.insert("ceiling_s".into(), Json::Num(CEILING_S));
    section.insert("fold_obs".into(), fold_metrics.to_json());
    let path = write_bench_json("scale", Json::Obj(section)).expect("bench json");
    println!("bench JSON -> {}", path.display());
}
