//! Bench (E3): the Eq. 13 vs Eq. 14 timing claim, quantified.
//!
//! For a sweep of gradient sizes, node counts and network speeds,
//! measures simulated per-iteration time of SSGD (blocking) and DC-S3GD
//! (overlapped) and compares each against its closed-form prediction:
//!
//!   t_SSGD    = t_C + t_AR          (Eq. 13)
//!   t_DC-S3GD = max(t_C, t_AR)      (Eq. 14)
//!
//! The crossover — where t_AR grows past t_C and the overlap stops
//! hiding communication completely — is the operative design point the
//! paper's method targets.

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn measure(algo: Algo, nodes: usize, net: NetModel, sec_per_sample: f64, steps: u64) -> f64 {
    let cfg = ExperimentConfig::builder("linear")
        .name(format!("ovl_{}_{nodes}", algo.name()).leak())
        .algo(algo)
        .nodes(nodes)
        .local_batch(32)
        .steps(steps)
        .eta_single(0.01)
        .base_batch(32)
        .data(2048, 256, 0.6)
        .net(net)
        .compute(ComputeModel::uniform(sec_per_sample))
        .build();
    run_experiment(&cfg).expect("run").mean_iter_time
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 20 } else { 60 };
    let n_params = 769 * 10 + 10; // linear model on 16×16×3, 10 classes

    println!("# Eq. 13 vs Eq. 14: predicted and measured iteration time\n");
    println!(
        "{:>4} {:>10} | {:>10} {:>10} {:>8} | {:>10} {:>10} {:>8} | {:>9}",
        "N", "β B/s", "ssgd", "eq13", "err%", "dcs3gd", "eq14", "err%", "speedup"
    );
    for &nodes in &[4usize, 8, 16] {
        for &beta in &[1e9, 1e8, 2e7, 5e6] {
            let net = NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: beta, algo: AllReduceAlgo::Ring };
            let t_c = 32.0 * 2e-4;
            let t_ar = net.allreduce_time(n_params, nodes);
            let eq13 = t_c + t_ar;
            let eq14 = t_c.max(t_ar);
            let ssgd = measure(Algo::Ssgd, nodes, net, 2e-4, steps);
            let dc = measure(Algo::DcS3gd, nodes, net, 2e-4, steps);
            println!(
                "{nodes:>4} {beta:>10.0e} | {ssgd:>10.6} {eq13:>10.6} {:>7.1}% | {dc:>10.6} {eq14:>10.6} {:>7.1}% | {:>8.2}x",
                100.0 * (ssgd - eq13).abs() / eq13,
                100.0 * (dc - eq14).abs() / eq14,
                ssgd / dc
            );
        }
    }
    println!(
        "\nExpected: measured columns track the closed forms within a few %,\n\
         speedup → (t_C+t_AR)/max(t_C,t_AR), maximal (≈2×) at t_C == t_AR."
    );
}
