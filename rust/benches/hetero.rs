//! Bench: the heterogeneous-fabric subsystem and the per-worker
//! staleness engines.
//!
//! * wall cost of resolving a fleet profile (the pure `(seed, rank)`
//!   draw functions) at cluster scale,
//! * the **heterogeneity table**: fixed-k DC-S3GD vs `dyn_ssp` vs `sgs`
//!   on the same mixed-tier + spot-revocation + diurnal fleet — sim
//!   wall-clock, wall-clock-to-target-loss, final loss. The acceptance
//!   row asserts the per-worker-bound controller (`dyn_ssp`) beats
//!   fixed-k on wall-clock to the shared target loss.
//!
//! The scenario is selected structurally (a seed scan over resolved
//! profiles), so the comparison is never vacuous: the post-revocation
//! fleet always keeps at least two ranks of each tier, and the
//! revocation always lands mid-run. The target loss is chosen as a
//! level every engine provably reaches (2% above the worst engine's
//! final trailing mean), so the time-to-target column is total.
//!
//! ```sh
//! DCS3GD_BENCH_FAST=1 cargo bench --bench hetero
//! ```

use std::collections::BTreeMap;

use dcs3gd::algo::{engine_registry, run_experiment, Algo, RunReport};
use dcs3gd::bench_util::{black_box, write_bench_json, Bencher};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::hetero::{HeteroConfig, HeteroProfile};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const NODES: usize = 8;
/// Trailing-mean window (in recorded steps) for the loss trajectory.
const WINDOW: usize = 48;

fn fleet() -> HeteroConfig {
    HeteroConfig {
        enabled: true,
        tiers: vec![1.0, 4.0],
        spot_fraction: 0.3,
        spot_mtbf_s: 0.5,
        spot_correlation: 0.5,
        diurnal_amplitude: 0.2,
        diurnal_period_s: 0.8,
        link_spread: 0.3,
        ..HeteroConfig::default()
    }
}

/// First seed whose resolved profile realizes the scenario: 1–2 spot
/// revocations landing mid-run, and at least two ranks of each tier
/// among the survivors. Pure profile arithmetic — no training runs.
fn pick_seed(h: &HeteroConfig) -> u64 {
    (0..4096u64)
        .find(|&s| {
            let p = HeteroProfile::resolve(h, s, NODES, NODES, 2);
            let revoked: Vec<usize> = p.revocations.iter().map(|r| r.0).collect();
            let timing_ok = !p.revocations.is_empty()
                && p.revocations.len() <= 2
                && p.revocations.iter().all(|&(_, t)| (0.3..=0.7).contains(&t));
            let survivors = |tier: f64| {
                (0..NODES).filter(|r| !revoked.contains(r) && p.tier[*r] == tier).count()
            };
            timing_ok && survivors(1.0) >= 2 && survivors(4.0) >= 2
        })
        .expect("a seed realizing the mixed-tier + spot scenario exists in 0..4096")
}

fn run_engine(algo: Algo, seed: u64, steps: u64) -> RunReport {
    let cfg = ExperimentConfig::builder("linear")
        .name(&format!("hetero_bench_{}", algo.name()))
        .algo(algo)
        .nodes(NODES)
        .local_batch(16)
        .steps(steps)
        .seed(seed)
        .eta_single(0.05)
        .base_batch(16)
        .data(4096, 512, 0.5)
        .compute(ComputeModel::uniform(1e-3)) // t_C = 16 ms / step at tier 1
        .staleness(8)
        .k_bounds(2, 8)
        .hetero(fleet())
        .build();
    run_experiment(&cfg).expect("hetero bench run")
}

/// All step records in simulated-time order (ties broken
/// deterministically), the x-axis of the loss-vs-wall-clock race.
fn timeline(r: &RunReport) -> Vec<(f64, f32)> {
    let mut steps = r.recorder.steps();
    steps.sort_by(|a, b| {
        a.sim_time
            .partial_cmp(&b.sim_time)
            .unwrap()
            .then(a.worker.cmp(&b.worker))
            .then(a.iteration.cmp(&b.iteration))
    });
    steps.iter().map(|s| (s.sim_time, s.loss)).collect()
}

/// Trailing mean over the last WINDOW points of the timeline — the
/// engine's settled loss level.
fn final_level(tl: &[(f64, f32)]) -> f64 {
    let tail = &tl[tl.len().saturating_sub(WINDOW)..];
    tail.iter().map(|&(_, l)| l as f64).sum::<f64>() / tail.len() as f64
}

/// First simulated time at which the trailing WINDOW-mean loss reaches
/// `target`. Total for any target >= final_level of the same timeline.
fn time_to_loss(tl: &[(f64, f32)], target: f64) -> Option<f64> {
    let mut sum = 0.0f64;
    for (i, &(t, l)) in tl.iter().enumerate() {
        sum += l as f64;
        if i >= WINDOW {
            sum -= tl[i - WINDOW].1 as f64;
        }
        let n = (i + 1).min(WINDOW);
        if n == WINDOW && sum / n as f64 <= target {
            return Some(t);
        }
    }
    None
}

fn main() {
    let fast = std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1");
    let steps: u64 = if fast { 64 } else { 128 };

    println!("# heterogeneity bench — profile resolution cost + the engine race\n");
    let mut b = Bencher::from_env();
    let h = fleet();
    for &cap in &[256usize, 4096] {
        b.bench_elems(&format!("hetero/resolve cap={cap}"), cap, || {
            black_box(HeteroProfile::resolve(&h, 7, cap, cap, 8).tier.len());
        });
    }
    b.report();

    let seed = pick_seed(&h);
    let profile = HeteroProfile::resolve(&h, seed, NODES, NODES, 2);
    println!(
        "\n# engine race: {NODES} ranks, tiers {:?}, seed {seed}, {steps} scheduled steps",
        profile.tier
    );
    println!("# spot revocations {:?}, diurnal ±20%, link spread 0.3", profile.revocations);

    // The bench-table rows come from the engine registry (fixed-k
    // dcs3gd first, then the per-worker-bound engines) — one list for
    // every staleness bench table.
    let engines: Vec<(Algo, RunReport)> = engine_registry()
        .iter()
        .filter(|e| e.bench_row)
        .map(|e| (e.algo, run_engine(e.algo, seed, steps)))
        .collect();
    let timelines: Vec<Vec<(f64, f32)>> = engines.iter().map(|(_, r)| timeline(r)).collect();
    // A loss level every engine provably reaches: 2% above the worst
    // settled level, so time_to_loss is Some for every row.
    let target = timelines.iter().map(|tl| final_level(tl)).fold(f64::MIN, f64::max) * 1.02;

    println!(
        "\n{:<10} {:>12} {:>16} {:>12} {:>8}",
        "engine", "sim time", "t to target", "final loss", "epochs"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut reach: Vec<f64> = Vec::new();
    for ((algo, r), tl) in engines.iter().zip(&timelines) {
        let t = time_to_loss(tl, target)
            .unwrap_or_else(|| panic!("{} never reached the shared target {target}", algo.name()));
        println!(
            "{:<10} {:>11.4}s {:>15.4}s {:>12.4} {:>8}",
            algo.name(),
            r.sim_time_s,
            t,
            r.final_train_loss,
            r.epochs.worlds().len(),
        );
        let mut m = BTreeMap::new();
        m.insert("engine".to_string(), Json::Str(algo.name().to_string()));
        m.insert("sim_time_s".into(), Json::Num(r.sim_time_s));
        m.insert("time_to_target_s".into(), Json::Num(t));
        m.insert("final_train_loss".into(), Json::Num(r.final_train_loss as f64));
        m.insert("worlds".into(), Json::Num(r.epochs.worlds().len() as f64));
        rows.push(Json::Obj(m));
        reach.push(t);
    }
    let idx = |name: &str| {
        engines.iter().position(|(a, _)| a.name() == name).expect("registry bench row")
    };
    let (t_fixed, t_dyn) = (reach[idx("dcs3gd")], reach[idx("dyn_ssp")]);
    let (fixed, dyn_ssp) = (&engines[idx("dcs3gd")].1, &engines[idx("dyn_ssp")].1);

    // Acceptance: the per-worker-bound controller beats fixed-k on
    // wall-clock to the shared target loss — fixed-k pays every window
    // at the slowest tier's pace, dyn_ssp rebalances the per-rank step
    // budgets toward equal wall time.
    assert!(
        t_dyn < t_fixed,
        "dyn_ssp must reach the target loss before fixed-k: {t_dyn} vs {t_fixed}"
    );
    assert!(
        dyn_ssp.sim_time_s < fixed.sim_time_s,
        "dyn_ssp must finish the step budget faster than fixed-k: {} vs {}",
        dyn_ssp.sim_time_s,
        fixed.sim_time_s
    );
    // and nobody falls out of the fixed-k loss envelope
    for (algo, r) in engines.iter().filter(|(a, _)| a.name() != "dcs3gd") {
        assert!(
            r.final_train_loss < fixed.final_train_loss * 1.5 + 0.25,
            "{} fell out of the fixed-k loss envelope: {} vs {}",
            algo.name(),
            r.final_train_loss,
            fixed.final_train_loss
        );
    }
    println!(
        "\n(dyn_ssp reached the target in {:.1}% of the fixed-k wall-clock)",
        100.0 * t_dyn / t_fixed
    );

    // Machine-readable export, merged into target/bench_results.json
    // next to the other sections (the CI perf artifact).
    let mut section = BTreeMap::new();
    section.insert("nodes".to_string(), Json::Num(NODES as f64));
    section.insert("steps".into(), Json::Num(steps as f64));
    section.insert("seed".into(), Json::Num(seed as f64));
    section.insert("tiers".into(), Json::Arr(profile.tier.iter().map(|&t| Json::Num(t)).collect()));
    section.insert(
        "revocations".into(),
        Json::Num(profile.revocations.len() as f64),
    );
    section.insert("target_loss".into(), Json::Num(target));
    section.insert("speedup_to_target".into(), Json::Num(t_fixed / t_dyn));
    section.insert("measurements".into(), b.results_json());
    section.insert("engines".into(), Json::Arr(rows));
    let path = write_bench_json("hetero", Json::Obj(section)).expect("bench json");
    println!("bench JSON -> {}", path.display());
}
